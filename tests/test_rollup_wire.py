"""Wire format v2 (FRU2) + delta algebra: frozen by a golden blob.

`tests/data/golden_rollup.fru2` was written once from `_gold_rollup()`
below; every future refactor must (a) ENCODE that rollup to the byte-
identical blob, and (b) DECODE the committed blob back to exactly the
frozen header fields and arrays — so a change that silently shifts the
header layout, column order, alignment, or meta JSON fails here before
it strands a fleet of per-host daemons mid-upgrade.

The property section pins the delta algebra itself: applying
`delta_bytes(a -> b)` to a mirror at `a` reproduces `b` bucketwise,
duplicates are dropped without double-counting, `merge_many` is the
pairwise `merge` fold, and both wire formats round-trip through the one
`from_bytes` entry point.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _propcheck import given, settings, st  # noqa: E402

from repro.fleet import wire  # noqa: E402
from repro.fleet.streaming import StreamingRollup, WindowedRollup  # noqa: E402

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLD_PATH = os.path.join(DATA, "golden_rollup.fru2")

# awkward floats on purpose: non-terminating binary fractions, exact
# zeros, repr-precision stress — byte-exactness must survive them all
GOLD_T_A = np.array([10.0, 30.0, 70.0])
GOLD_V_A = np.array([0.1, 1.0 / 3.0, 0.4123456789012345])
GOLD_T_B = np.array([70.0, 130.0])
GOLD_V_B = np.array([0.25, 0.0078125])
GOLD_META = {"job-a": {"chips": 256, "app_mfu": 0.381,
                       "arch": "granite-3-2b", "flops_variant": "bf16"}}

# frozen decode expectations for the blob above
GOLD_SCOPES = [("job", "job-a"), ("group", "bf16"),
               ("group", "__fleet__"), ("job", "job-b"), ("group", "fp8")]
GOLD_SEQ, GOLD_BINS, GOLD_N_BUCKETS, GOLD_BUCKET_S = 2, 8, 3, 60.0


def _gold_rollup() -> StreamingRollup:
    roll = StreamingRollup(GOLD_BUCKET_S, bins=GOLD_BINS, lo=0.0, hi=1.1)
    roll.observe("job-a", GOLD_T_A, GOLD_V_A, group="bf16")
    roll.observe("job-b", GOLD_T_B, GOLD_V_B, group="fp8", weight=2.0)
    roll._job_meta = {k: dict(v) for k, v in GOLD_META.items()}
    return roll


def _rand_rollup(rng, *, bins=8, n_jobs=2, rounds=3) -> StreamingRollup:
    roll = StreamingRollup(60.0, bins=bins, lo=0.0, hi=1.1)
    for r in range(rounds):
        for j in range(n_jobs):
            n = int(rng.integers(1, 6))
            t = rng.uniform(r * 120.0, (r + 1) * 120.0, n)
            roll.observe(f"job-{j}", t, rng.uniform(0.0, 1.0, n),
                         group="bf16" if j % 2 else "fp8",
                         weight=float(rng.integers(1, 4)))
    return roll


def _assert_same_state(a: StreamingRollup, b: StreamingRollup,
                       exact: bool = True) -> None:
    assert set(a._hists) == set(b._hists)
    for scope in a._hists:
        ah, bh = a._hists[scope], b._hists[scope]
        n = max(ah.shape[0], bh.shape[0])

        def grow(x, rows):
            out = np.zeros((rows,) + x.shape[1:])
            out[:x.shape[0]] = x
            return out
        if exact:
            np.testing.assert_array_equal(grow(ah, n), grow(bh, n),
                                          err_msg=f"scope {scope}")
            np.testing.assert_array_equal(grow(a._sums[scope], n),
                                          grow(b._sums[scope], n))
        else:
            np.testing.assert_allclose(grow(ah, n), grow(bh, n),
                                       rtol=1e-12, atol=1e-12,
                                       err_msg=f"scope {scope}")
            np.testing.assert_allclose(grow(a._sums[scope], n),
                                       grow(b._sums[scope], n),
                                       rtol=1e-12, atol=1e-12)


# -- golden blob: byte-exact encode, exact decode ------------------------
def test_golden_encode_is_byte_exact():
    with open(GOLD_PATH, "rb") as f:
        frozen = f.read()
    assert _gold_rollup().to_bytes_v2() == frozen, \
        "FRU2 encoding changed: the blob no longer matches the " \
        "committed fixture (header layout / column order / meta JSON)"


def test_golden_decode_is_exact():
    with open(GOLD_PATH, "rb") as f:
        blob = f.read()
    snap = wire.decode(blob)
    assert snap.version == wire.VERSION
    assert not snap.is_delta and snap.since == 0
    assert snap.seq == GOLD_SEQ
    assert snap.bins == GOLD_BINS
    assert snap.n_buckets == GOLD_N_BUCKETS
    assert snap.bucket_s == GOLD_BUCKET_S
    assert [s[0] for s in snap.scopes] == GOLD_SCOPES
    assert snap.job_meta == GOLD_META
    gold = _gold_rollup()
    for scope, idx, hist, sums in snap.scopes:
        np.testing.assert_array_equal(hist, gold._hists[scope][idx])
        np.testing.assert_array_equal(sums, gold._sums[scope][idx])
    # one hand-frozen probe: job-b's 130 s sample lands in bucket 2
    # (right-closed) with weight 2.0 and sums 2 * 0.0078125 exactly
    jb = dict((s[0], s) for s in snap.scopes)[("job", "job-b")]
    assert list(jb[1]) == [1, 2]
    assert jb[3][1] == 2 * 0.0078125


def test_golden_restores_through_from_bytes():
    with open(GOLD_PATH, "rb") as f:
        blob = f.read()
    roll = StreamingRollup.from_bytes(blob)
    _assert_same_state(roll, _gold_rollup())
    assert roll.generation == GOLD_SEQ
    assert roll.job_meta("job-a") == GOLD_META["job-a"]


# -- zero-copy + validation ----------------------------------------------
def test_decode_returns_views_into_the_blob():
    blob = _gold_rollup().to_bytes_v2()
    raw = np.frombuffer(blob, np.uint8)
    snap = wire.decode(blob)
    for arr in (snap.edges, *(a for s in snap.scopes for a in s[1:])):
        assert not arr.flags.writeable
        assert np.shares_memory(arr, raw), \
            "decode must alias the blob, not copy out of it"


def test_decode_rejects_corruption():
    blob = _gold_rollup().to_bytes_v2()
    with pytest.raises(ValueError, match="magic"):
        wire.decode(b"XXXX" + blob[4:])
    with pytest.raises(ValueError, match="truncated"):
        wire.decode(blob[:-16])
    with pytest.raises(ValueError, match="too short"):
        wire.decode(blob[:12])
    with pytest.raises(ValueError, match="version"):
        wire.decode(blob[:4] + b"\x63\x00" + blob[6:])


def test_windowed_rollups_stay_on_npz():
    win = WindowedRollup(60.0, bins=8, retain=4)
    win.observe("j", np.array([30.0]), np.array([0.5]))
    with pytest.raises(ValueError, match="npz"):
        win.to_bytes_v2()
    with pytest.raises(ValueError, match="npz|windowed"):
        win.apply_snapshot(wire.decode(_gold_rollup().to_bytes_v2()))
    # but the npz path still round-trips it through the same entry point
    back = StreamingRollup.from_bytes(win.to_bytes())
    assert isinstance(back, WindowedRollup)


def test_restore_refuses_delta_blobs():
    roll = _gold_rollup()
    gen = roll.generation
    roll.observe("job-a", np.array([200.0]), np.array([0.9]),
                 group="bf16")
    with pytest.raises(ValueError, match="delta"):
        StreamingRollup.from_bytes(roll.delta_bytes(gen))


# -- cross-format round-trip ---------------------------------------------
@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_v2_and_npz_round_trip_identically(seed):
    roll = _rand_rollup(np.random.default_rng(seed))
    via_npz = StreamingRollup.from_bytes(roll.to_bytes())
    via_v2 = StreamingRollup.from_bytes(roll.to_bytes_v2())
    _assert_same_state(via_npz, roll)
    _assert_same_state(via_v2, roll)
    assert via_v2._job_meta == roll._job_meta
    # and the restored rollup re-encodes to the byte-identical v2 blob
    assert via_v2.to_bytes_v2() == roll.to_bytes_v2()


# -- delta algebra --------------------------------------------------------
@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=1, max_value=4))
def test_delta_applied_to_base_reproduces_target(seed, extra_rounds):
    """apply(mirror_at_a, delta(a -> b)) == b, bucketwise exact."""
    rng = np.random.default_rng(seed)
    roll = _rand_rollup(rng)
    mirror = StreamingRollup.from_bytes(roll.to_bytes_v2())
    cut = roll.generation
    for r in range(extra_rounds):
        n = int(rng.integers(1, 5))
        roll.observe(f"job-{int(rng.integers(0, 3))}",
                     rng.uniform(0.0, 600.0, n),
                     rng.uniform(0.0, 1.0, n), group="bf16")
    delta = roll.delta_bytes(cut)
    assert len(delta) <= len(roll.to_bytes_v2())
    assert mirror.apply_delta(delta) is True
    _assert_same_state(mirror, roll)
    assert mirror.generation == roll.generation


@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_duplicate_delivery_is_idempotent(seed):
    rng = np.random.default_rng(seed)
    roll = _rand_rollup(rng)
    mirror = roll.spawn_empty()
    full = roll.delta_bytes(0)
    assert mirror.apply_delta(full) is True
    before = {s: mirror._hists[s].copy() for s in mirror._hists}
    # at-least-once: the same blob again, and a stale re-cut
    assert mirror.apply_delta(full) is False
    assert mirror.apply_delta(roll.delta_bytes(0)) is False
    for s, h in before.items():
        np.testing.assert_array_equal(mirror._hists[s], h)
    _assert_same_state(mirror, roll)


def test_gap_detection_names_the_generations():
    roll = _rand_rollup(np.random.default_rng(0))
    mirror = roll.spawn_empty()
    cut = roll.generation
    roll.observe("job-0", np.array([50.0]), np.array([0.5]))
    with pytest.raises(ValueError, match="gap"):
        mirror.apply_delta(roll.delta_bytes(cut))
    # recovery: a full blob (since=0) always applies
    assert mirror.apply_delta(roll.delta_bytes(0)) is True
    _assert_same_state(mirror, roll)


# -- merge_many == pairwise fold ------------------------------------------
@settings(max_examples=15)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=1, max_value=9))
def test_merge_many_matches_pairwise_fold(seed, k):
    rng = np.random.default_rng(seed)
    parts = [_rand_rollup(rng, rounds=int(rng.integers(1, 4)))
             for _ in range(k)]
    pairwise = parts[0].spawn_empty()
    for p in parts:
        pairwise.merge(p)
    kway = parts[0].spawn_empty().merge_many(parts)
    _assert_same_state(kway, pairwise, exact=False)
    assert kway._job_meta == pairwise._job_meta


def test_merge_many_windowed_falls_back_to_pairwise():
    rng = np.random.default_rng(3)
    parts = []
    for i in range(4):
        win = WindowedRollup(60.0, bins=8, retain=4)
        t = rng.uniform(0.0, 600.0, 8)
        win.observe(f"job-{i % 2}", t, rng.uniform(0.0, 1.0, 8))
        parts.append(win)
    pairwise = parts[0].spawn_empty()
    for p in parts:
        pairwise.merge(p)
    kway = parts[0].spawn_empty().merge_many(parts)
    assert isinstance(kway, WindowedRollup)
    for scope in pairwise._hists:
        np.testing.assert_allclose(kway._hists[scope],
                                   pairwise._hists[scope], rtol=1e-12)


def test_merge_many_rejects_mismatched_bucketing():
    a = StreamingRollup(60.0, bins=8)
    b = StreamingRollup(60.0, bins=16)
    b.observe("j", np.array([30.0]), np.array([0.5]))
    with pytest.raises(ValueError, match="bucketing"):
        a.merge_many([b])
