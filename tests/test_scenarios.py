"""Scenario library + fault-injection layer (ISSUE 8 tentpole).

Covers the `CounterFault` post-hoc perturbation engine (masking, device
subsets, periodic/diurnal gating, clipping, validation), the central
post-hoc guarantee — simulating WITH faults equals applying faults to
the SAME simulation without them, on every engine backend — and the
labeled scenario library's registry, determinism, and label hygiene.
"""
import numpy as np
import pytest

from repro.fleet.engine import CounterFault, apply_faults, fault_factors
from repro.fleet.jobs import JobSpec, simulate_fleet, simulate_job
from repro.scenarios import SCENARIOS, GroundTruthEvent, Scenario, build
from repro.telemetry.scrape import DeviceGrid


def _times(n, interval=30.0):
    return interval + interval * np.arange(n)


# ---------------------------------------------------------------------------
# fault_factors: the (duty, clock) mask algebra
# ---------------------------------------------------------------------------
def test_fault_window_masks_time_and_all_devices():
    t = _times(10)
    duty, clock = fault_factors(
        [CounterFault(start_s=120.0, end_s=240.0, duty_scale=0.4,
                      clock_scale=0.7)], t, 3)
    on = (t >= 120.0) & (t < 240.0)
    assert duty.shape == clock.shape == (3, 10)
    np.testing.assert_allclose(duty[:, on], 0.4)
    np.testing.assert_allclose(duty[:, ~on], 1.0)
    np.testing.assert_allclose(clock[:, on], 0.7)
    np.testing.assert_allclose(clock[:, ~on], 1.0)


def test_fault_device_subsets():
    t = _times(4)
    # explicit device rows
    duty, _ = fault_factors([CounterFault(duty_scale=0.5, devices=(0, 2))],
                            t, 4)
    np.testing.assert_allclose(duty[[0, 2]], 0.5)
    np.testing.assert_allclose(duty[[1, 3]], 1.0)
    # fractional: ceil(0.5 * 4) = first 2 rows
    duty, _ = fault_factors([CounterFault(duty_scale=0.5,
                                          device_frac=0.5)], t, 4)
    np.testing.assert_allclose(duty[:2], 0.5)
    np.testing.assert_allclose(duty[2:], 1.0)
    with pytest.raises(ValueError, match="device"):
        fault_factors([CounterFault(devices=(5,))], t, 4)


def test_fault_periodic_gating():
    t = _times(12, interval=10.0)          # 10..120
    duty, _ = fault_factors(
        [CounterFault(start_s=10.0, duty_scale=0.2, period_s=40.0,
                      active_frac=0.5)], t, 1)
    # active while (t - 10) mod 40 < 20
    on = np.mod(t - 10.0, 40.0) < 20.0
    on &= t >= 10.0
    np.testing.assert_allclose(duty[0, on], 0.2)
    np.testing.assert_allclose(duty[0, ~on], 1.0)


def test_fault_diurnal_wave():
    t = _times(8, interval=100.0)
    duty, _ = fault_factors(
        [CounterFault(diurnal_amp=0.25, diurnal_period_s=800.0)], t, 2)
    want = 1.0 + 0.25 * np.sin(2 * np.pi * t / 800.0)
    np.testing.assert_allclose(duty[0], want, rtol=1e-6)
    np.testing.assert_allclose(duty[1], want, rtol=1e-6)


def test_faults_compound_multiplicatively():
    t = _times(6)
    f1 = CounterFault(duty_scale=0.5)
    f2 = CounterFault(start_s=90.0, duty_scale=0.4, clock_scale=0.8)
    duty, clock = fault_factors([f1, f2], t, 1)
    on = t >= 90.0
    np.testing.assert_allclose(duty[0, on], 0.2)
    np.testing.assert_allclose(duty[0, ~on], 0.5)
    np.testing.assert_allclose(clock[0, on], 0.8)


def test_fault_validation():
    with pytest.raises(ValueError):
        CounterFault(start_s=100.0, end_s=50.0)
    with pytest.raises(ValueError):
        CounterFault(device_frac=0.0)
    with pytest.raises(ValueError):
        CounterFault(device_frac=1.5)
    with pytest.raises(ValueError):
        CounterFault(period_s=100.0, active_frac=0.0)
    with pytest.raises(ValueError):
        CounterFault(diurnal_amp=1.5)


# ---------------------------------------------------------------------------
# apply_faults: grid semantics
# ---------------------------------------------------------------------------
def _grid(n_dev=2, n_s=6, tpa=0.5, clock=1200.0):
    return DeviceGrid(30.0, np.full((n_dev, n_s), tpa),
                      np.full((n_dev, n_s), clock), t0_s=0.0)


def test_apply_faults_empty_is_noop():
    g = _grid()
    out = apply_faults(g, [])
    np.testing.assert_array_equal(out.tpa, g.tpa)
    np.testing.assert_array_equal(out.clock_mhz, g.clock_mhz)
    assert out.interval_s == g.interval_s and out.t0_s == g.t0_s


def test_apply_faults_scales_and_clips():
    g = _grid(tpa=0.8, clock=1000.0)
    out = apply_faults(g, [CounterFault(duty_scale=1.5, clock_scale=0.5)])
    np.testing.assert_allclose(out.tpa, 1.0)          # clipped at 1
    np.testing.assert_allclose(out.clock_mhz, 500.0)
    assert out.t0_s == g.t0_s and out.interval_s == g.interval_s
    # and the input grid is untouched
    np.testing.assert_allclose(g.tpa, 0.8)


# ---------------------------------------------------------------------------
# The post-hoc guarantee: faults never change the underlying realization
# ---------------------------------------------------------------------------
FAULTS = [CounterFault(start_s=300.0, duty_scale=0.4, clock_scale=0.9)]


def _spec(faults=(), **kw):
    kw.setdefault("duration_s", 600.0)
    kw.setdefault("chips", 8)
    return JobSpec("posthoc", "llama3.2-3b", seed=3, faults=list(faults),
                   **kw)


@pytest.mark.parametrize("engine", ["vector", "scalar"])
def test_posthoc_equals_apply_after_the_fact(engine):
    base = simulate_job(_spec(), engine=engine)
    faulted = simulate_job(_spec(FAULTS), engine=engine)
    want = apply_faults(base.grid, FAULTS)
    np.testing.assert_array_equal(faulted.grid.tpa, want.tpa)
    np.testing.assert_array_equal(faulted.grid.clock_mhz, want.clock_mhz)
    # app-side numbers are untouched: the app doesn't know it regressed
    assert faulted.app_mfu == base.app_mfu
    assert faulted.step_time_s == base.step_time_s


def test_posthoc_jax_engine_matches_declared_perturbation():
    jax = pytest.importorskip("jax")  # noqa: F841
    base = simulate_job(_spec(), engine="jax")
    faulted = simulate_job(_spec(FAULTS), engine="jax")
    want = apply_faults(base.grid, FAULTS)
    np.testing.assert_allclose(np.asarray(faulted.grid.tpa),
                               np.asarray(want.tpa), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(faulted.grid.clock_mhz),
                               np.asarray(want.clock_mhz), rtol=1e-6)


def test_posthoc_fused_fleet_faults_only_hit_their_job():
    specs = [_spec(), JobSpec("bystander", "qwen3-4b", seed=4,
                              duration_s=600.0, chips=8)]
    plain = simulate_fleet(specs, engine="fused")
    specs_f = [_spec(FAULTS), JobSpec("bystander", "qwen3-4b", seed=4,
                                      duration_s=600.0, chips=8)]
    faulted = simulate_fleet(specs_f, engine="fused")
    want = apply_faults(plain[0].grid, FAULTS)
    np.testing.assert_array_equal(faulted[0].grid.tpa, want.tpa)
    # the unfaulted job's realization is bit-identical
    np.testing.assert_array_equal(faulted[1].grid.tpa, plain[1].grid.tpa)


# ---------------------------------------------------------------------------
# the library
# ---------------------------------------------------------------------------
def test_library_has_the_required_scenarios():
    names = set(SCENARIOS)
    assert len(names) >= 6
    assert {"gloo_regression_2p5x", "mixed_precision_transition",
            "straggler_hosts", "thermal_throttle", "preemption_wave",
            "moe_expert_imbalance", "diurnal_inference"} <= names


def test_build_is_deterministic():
    a, b = build("gloo_regression_2p5x"), build("gloo_regression_2p5x")
    assert [s.job_id for s in a.specs] == [s.job_id for s in b.specs]
    assert a.labels == b.labels
    ga = simulate_fleet(a.specs, engine="fused")
    gb = simulate_fleet(b.specs, engine="fused")
    for ta, tb in zip(ga, gb):
        np.testing.assert_array_equal(ta.grid.tpa, tb.grid.tpa)
        np.testing.assert_array_equal(ta.grid.clock_mhz, tb.grid.clock_mhz)


def test_build_unknown_name():
    with pytest.raises(KeyError, match="unknown scenario"):
        build("nope")


def test_paper_scenario_carries_the_2p5x_ground_truth():
    sc = build("gloo_regression_2p5x")
    (lbl,) = sc.labels
    assert lbl.detector == "regression"
    assert lbl.magnitude == pytest.approx(2.5)
    (bad,) = [s for s in sc.specs if s.faults]
    assert bad.job_id == lbl.job_id
    assert bad.faults[0].duty_scale == pytest.approx(0.4)   # 1/2.5


def test_diurnal_scenario_is_the_false_positive_probe():
    sc = build("diurnal_inference")
    assert sc.labels == []
    assert all(s.faults for s in sc.specs)      # benign faults everywhere


def test_scenario_label_hygiene():
    spec = JobSpec("a", "llama3.2-3b")
    with pytest.raises(ValueError, match="unknown job"):
        Scenario("x", "d", [spec],
                 [GroundTruthEvent("ghost", "regression", 10.0)])
    with pytest.raises(ValueError, match="unknown detector"):
        GroundTruthEvent("a", "oracle", 10.0)
    with pytest.raises(ValueError, match="empty"):
        GroundTruthEvent("a", "regression", 10.0, end_s=5.0)
    with pytest.raises(ValueError, match="duplicate"):
        Scenario("x", "d", [spec, JobSpec("a", "qwen3-4b")], [])
