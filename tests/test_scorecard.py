"""Detector scorecard (ISSUE 8 tentpole): scoring semantics, the pinned
precision/recall/time-to-detect floors, the frozen scorecard document,
and the golden fault-injected archive fixture.

The full-library replay runs ONCE per module (it is the same run CI's
scorecard job performs) and every downstream assertion reads from it.
"""
import json
import os

import numpy as np
import pytest

from repro.fleet.collector import Alert
from repro.fleet.engine import CounterFault, apply_faults
from repro.scenarios import (FLOORS, SCHEMA, GroundTruthEvent, Scenario,
                             build, check_floors, run_scenario,
                             run_scorecard, score_alerts)
from repro.fleet.jobs import JobSpec
from repro.telemetry import read_trace
from repro.telemetry.scrape import DeviceGrid

DATA = os.path.join(os.path.dirname(__file__), "data")

# tools/ is scripts, not a package: load the CLI module by path
import importlib.util  # noqa: E402

_spec = importlib.util.spec_from_file_location(
    "fleet_scorecard", os.path.join(os.path.dirname(DATA), "..",
                                    "tools", "fleet_scorecard.py"))
fleet_scorecard = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(fleet_scorecard)
_merge_bench_json, main = fleet_scorecard._merge_bench_json, \
    fleet_scorecard.main


@pytest.fixture(scope="module")
def card():
    """One full-library scorecard — the exact document CI gates on."""
    return run_scorecard()


# ---------------------------------------------------------------------------
# scoring semantics (synthetic alerts, no simulation)
# ---------------------------------------------------------------------------
def _toy_scenario(labels, tolerance_s=100.0):
    return Scenario("toy", "toy", [JobSpec("a", "llama3.2-3b",
                                           duration_s=1000.0),
                                   JobSpec("b", "qwen3-4b",
                                           duration_s=1000.0)],
                    labels, tolerance_s=tolerance_s)


def _alert(job_id, kind, t_s, round_idx=1):
    return Alert(round_idx, t_s, job_id, kind, "msg", factor=2.0)


def test_score_matching_precision_recall_ttd():
    sc = _toy_scenario([
        GroundTruthEvent("a", "regression", 200.0, end_s=400.0),
        GroundTruthEvent("b", "regression", 600.0),
    ])
    alerts = [
        _alert("a", "regression", 300.0),     # matches label 1, ttd 100
        _alert("a", "regression", 950.0),     # outside a's window: FP
        _alert("b", "divergence", 700.0),     # wrong kind for the label
    ]
    s = score_alerts(sc, alerts)["regression"]
    assert s.n_alerts == 2 and s.n_matched_alerts == 1
    assert s.precision == pytest.approx(0.5)
    assert s.n_labels == 2 and s.n_matched_labels == 1
    assert s.recall == pytest.approx(0.5)
    assert s.ttd_s == pytest.approx(100.0)
    # the divergence alert is scored under its own detector, as a FP
    d = score_alerts(sc, alerts)["divergence"]
    assert d.precision == 0.0 and d.recall == 1.0 and d.n_labels == 0


def test_score_tolerance_window_extends_label_end():
    sc = _toy_scenario([GroundTruthEvent("a", "regression", 200.0,
                                         end_s=400.0)], tolerance_s=150.0)
    assert score_alerts(sc, [_alert("a", "regression", 540.0)]) \
        ["regression"].recall == 1.0
    assert score_alerts(sc, [_alert("a", "regression", 560.0)]) \
        ["regression"].recall == 0.0
    # an alert BEFORE onset never matches (detection cannot precede cause)
    assert score_alerts(sc, [_alert("a", "regression", 150.0)]) \
        ["regression"].precision == 0.0


def test_score_silent_and_unlabeled_edge_cases():
    sc = _toy_scenario([])
    s = score_alerts(sc, [])["regression"]
    assert s.precision == 1.0 and s.recall == 1.0 and s.ttd_s is None


# ---------------------------------------------------------------------------
# the paper scenario + the full-library scorecard
# ---------------------------------------------------------------------------
def test_paper_2p5x_scenario_scores_perfectly():
    sc = build("gloo_regression_2p5x")
    run = run_scenario(sc)
    s = score_alerts(sc, run.alerts)["regression"]
    assert s.precision == 1.0 and s.recall == 1.0
    assert s.ttd_s is not None and s.ttd_s <= 1200.0
    # the alert carries (roughly) the injected 2.5x magnitude
    (a,) = [a for a in run.alerts if a.kind == "regression"]
    assert a.factor == pytest.approx(2.5, rel=0.2)


def test_scorecard_covers_all_detectors_on_all_scenarios(card):
    assert len(card["scenarios"]) >= 6
    for entry in card["scenarios"].values():
        assert set(entry["detectors"]) \
            == {"regression", "divergence", "goodput", "miscalc"}


def test_scorecard_holds_every_pinned_floor(card):
    assert check_floors(card) == []


def test_check_floors_flags_doctored_results(card):
    doc = json.loads(json.dumps(card))
    cell = doc["scenarios"]["gloo_regression_2p5x"] \
              ["detectors"]["regression"]
    cell["precision"] = 0.5
    cell["ttd_s"] = 99999.0
    bad = check_floors(doc)
    assert any("precision 0.500" in v for v in bad)
    assert any("ttd 99999s" in v for v in bad)
    # an undetected floored cell and a missing scenario both violate
    cell["ttd_s"] = None
    del doc["scenarios"]["thermal_throttle"]
    bad = check_floors(doc)
    assert any("no detection" in v for v in bad)
    assert any("thermal_throttle/regression: missing" in v for v in bad)
    # every floor key refers to a real (scenario, detector) cell
    for scen, det in FLOORS:
        assert det in card["scenarios"][scen]["detectors"], (scen, det)


def test_scorecard_document_is_frozen(card):
    """The committed golden scorecard pins BOTH the schema shape and the
    measured values: a detector or engine change that moves any score
    must regenerate tests/data/golden_scorecard.json deliberately
    (PYTHONPATH=src python tools/fleet_scorecard.py
    --json tests/data/golden_scorecard.json --no-bench-json)."""
    with open(os.path.join(DATA, "golden_scorecard.json")) as fh:
        golden = json.load(fh)
    assert card["schema"] == SCHEMA == golden["schema"]
    assert card == golden


# ---------------------------------------------------------------------------
# golden fault-injected archive
# ---------------------------------------------------------------------------
def _golden_base_grid():
    d, s = 3, 20
    iv, t0 = 30.0, 300.0
    tpa = 0.3 + 0.15 * np.sin(2 * np.pi * np.arange(d)[:, None] / 3.0
                              + np.arange(s) / 7.0)
    clk = 1300.0 - 50.0 * np.cos(np.arange(s) / 5.0) \
        + 10.0 * np.arange(d)[:, None]
    return DeviceGrid(iv, tpa, clk, t0_s=t0)


GOLDEN_FAULTS = [
    CounterFault(start_s=600.0, duty_scale=0.4, kind="gloo_regression"),
    CounterFault(start_s=450.0, end_s=750.0, clock_scale=0.7,
                 devices=(1,), kind="thermal"),
]


def test_golden_scenario_archive_is_exact():
    """tests/data/golden_scenario.ctr freezes the fault layer's output:
    re-applying the same `CounterFault`s to the same deterministic base
    grid must reproduce the committed archive EXACTLY, so a semantic
    drift in masking/compounding/clipping fails here before it silently
    relabels every scenario."""
    want = apply_faults(_golden_base_grid(), GOLDEN_FAULTS)
    got = read_trace(os.path.join(DATA, "golden_scenario.ctr"))
    assert got.interval_s == want.interval_s
    assert got.t0_s == want.t0_s
    np.testing.assert_array_equal(got.tpa, want.tpa)
    np.testing.assert_array_equal(got.clock_mhz, want.clock_mhz)


# ---------------------------------------------------------------------------
# the CLI
# ---------------------------------------------------------------------------
def test_cli_single_scenario_exits_clean(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("BENCH_FLEET_JSON", str(tmp_path / "bench.json"))
    out_json = tmp_path / "card.json"
    assert main(["--scenario", "gloo_regression_2p5x",
                 "--json", str(out_json)]) == 0
    doc = json.loads(out_json.read_text())
    assert list(doc["scenarios"]) == ["gloo_regression_2p5x"]
    lines = [l for l in capsys.readouterr().out.splitlines()
             if l.startswith("BENCH ")]
    names = {json.loads(l[6:])["name"] for l in lines}
    assert "scorecard/gloo_regression_2p5x/regression" in names
    bench = json.loads((tmp_path / "bench.json").read_text())
    assert {c["name"] for c in bench["cases"]} == names


def test_bench_json_merges_by_case_name(tmp_path, monkeypatch):
    path = tmp_path / "bench.json"
    monkeypatch.setenv("BENCH_FLEET_JSON", str(path))
    path.write_text(json.dumps({
        "schema": 1, "suite": "fleet_engine",
        "cases": [{"name": "engine/foo", "median": 1.0, "units": "ms",
                   "metrics": {}},
                  {"name": "scorecard/x/regression", "median": 0.5,
                   "units": "precision", "metrics": {}}]}))
    _merge_bench_json([{"name": "scorecard/x/regression", "median": 1.0,
                        "units": "precision", "metrics": {}}])
    doc = json.loads(path.read_text())
    by_name = {c["name"]: c for c in doc["cases"]}
    assert len(doc["cases"]) == 2                     # no duplicates
    assert by_name["engine/foo"]["median"] == 1.0     # other suite kept
    assert by_name["scorecard/x/regression"]["median"] == 1.0  # replaced
    # a corrupt file is rewritten, not crashed on
    path.write_text("{not json")
    _merge_bench_json([{"name": "a", "median": 0, "units": "x",
                        "metrics": {}}])
    assert [c["name"] for c in json.loads(path.read_text())["cases"]] \
        == ["a"]
