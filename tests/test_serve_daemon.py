"""ServiceDaemon lifecycle coverage (ISSUE 5): wall-clock pacing with
drift correction on an injected clock, graceful mid-run stream churn
(bucketwise-consistent rollups), snapshot persist → restart restore →
continue equivalence through the FleetStore, and the crash-safe
recording tee (a killed daemon leaves replayable archives up to the
last persistence point; a restored one continues them gaplessly).
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.fleet.collector import (Alert, Collector, CollectorConfig,
                                   JobStream)
from repro.fleet.engine import simulate_devices
from repro.fleet.streaming import WindowedRollup
from repro.serve.daemon import ServiceDaemon, SimClock
from repro.telemetry import (Event, SimulatorSource, StepProfile,
                             TraceReplaySource, write_trace)
from repro.telemetry.source import read_trace

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)


def _sim_stream(job_id, duration_s=1800, seed=0, **kw):
    return JobStream(job_id, SimulatorSource(
        PROFILE, duration_s=duration_s, interval_s=30, n_devices=2,
        seed=seed), chips=32, group="bf16", **kw)


def _cfg(**kw):
    kw.setdefault("round_s", 300)
    kw.setdefault("bucket_s", 300)
    kw.setdefault("retain", 8)
    kw.setdefault("detector", {"window": 3, "min_duration": 1})
    return CollectorConfig(**kw)


def _archive(tmp_path, name="trace.ctr", duration_s=3600,
             chunk_samples=40, seed=21):
    grid = simulate_devices(PROFILE, duration_s=duration_s,
                            interval_s=30.0,
                            events=[Event(duration_s / 2, duration_s,
                                          slowdown=2.5)],
                            n_devices=4, seed=seed)
    path = str(tmp_path / name)
    write_trace(grid, path, chunk_samples=chunk_samples)
    return path, grid


def _replay_streams(path):
    return [JobStream("traced", TraceReplaySource(path), chips=128,
                      group="bf16", app_mfu=0.38)]


# ---------------------------------------------------------------------------
# Wall-clock pacing
# ---------------------------------------------------------------------------
class _SlowRoundCollector(Collector):
    """Collector whose rounds 'take' fixed wall time on a SimClock."""

    def __init__(self, *args, clk=None, costs=(), **kw):
        super().__init__(*args, **kw)
        self._clk = clk
        self._costs = list(costs)

    def poll_round(self):
        if self._costs:
            self._clk.advance(self._costs.pop(0))
        return super().poll_round()


def test_daemon_sleeps_to_deadline_with_drift_correction():
    clk = SimClock()
    col = _SlowRoundCollector([_sim_stream("j", duration_s=1500)], _cfg(),
                              clk=clk, costs=[40.0] * 5)
    daemon = ServiceDaemon(col, clock=clk.monotonic, sleep=clk.sleep)
    reports = daemon.run()
    assert len(reports) == 5
    # each round costs 40 s; deadlines are origin + k*300, so every sleep
    # is exactly the 260 s of slack — drift never accumulates
    assert clk.sleeps == pytest.approx([260.0] * 4)   # no sleep after last
    assert daemon.overruns == 0


def test_daemon_overrun_skips_sleep_and_does_not_shift_later_deadlines():
    clk = SimClock()
    col = _SlowRoundCollector([_sim_stream("j", duration_s=1500)], _cfg(),
                              clk=clk, costs=[40.0, 350.0, 40.0, 40.0, 40.0])
    daemon = ServiceDaemon(col, clock=clk.monotonic, sleep=clk.sleep)
    daemon.run()
    assert daemon.overruns == 1
    # round 2 blows its 600 s deadline (ends at 650); round 3 ends at 690
    # and sleeps only the 210 s back to the ORIGIN-anchored 900 s deadline
    assert clk.sleeps == pytest.approx([260.0, 210.0, 260.0])


def test_daemon_unpaced_run_never_sleeps():
    clk = SimClock()
    daemon = ServiceDaemon(
        Collector([_sim_stream("j", duration_s=1200)], _cfg()),
        clock=clk.monotonic, sleep=clk.sleep, pace=False)
    daemon.run()
    assert clk.sleeps == []


def test_daemon_requires_bounded_streams_without_n_rounds():
    live = _sim_stream("live", duration_s=float("inf"))
    clk = SimClock()
    daemon = ServiceDaemon(Collector([live], _cfg()),
                           clock=clk.monotonic, sleep=clk.sleep)
    with pytest.raises(ValueError, match="unbounded"):
        daemon.run()
    assert len(daemon.run(n_rounds=2)) == 2


# ---------------------------------------------------------------------------
# Stream churn
# ---------------------------------------------------------------------------
class _RecordingSource(SimulatorSource):
    def poll(self, duration_s):
        grid = super().poll(duration_s)
        self.__dict__.setdefault("polled", []).append(grid)
        return grid


def test_stream_churn_keeps_rollup_bucketwise_consistent():
    a = JobStream("a", _RecordingSource(PROFILE, duration_s=2400,
                                        interval_s=30, n_devices=2,
                                        seed=1), chips=32, group="bf16")
    b = JobStream("b", _RecordingSource(PROFILE, duration_s=2400,
                                        interval_s=30, n_devices=2,
                                        seed=2), chips=32, group="bf16")
    c = JobStream("c", _RecordingSource(PROFILE, duration_s=1200,
                                        interval_s=30, n_devices=2,
                                        seed=3), chips=32, group="bf16")
    clk = SimClock()
    daemon = ServiceDaemon(Collector([a, b], _cfg()),
                           clock=clk.monotonic, sleep=clk.sleep)
    daemon.run(n_rounds=2)
    daemon.request_add_stream(c)          # joins at round 3
    daemon.run(n_rounds=2)
    daemon.request_remove_stream("b")     # leaves before round 5
    daemon.run()
    assert daemon.done

    # manual reference: ingest exactly the grids the daemon polled
    ref = WindowedRollup(bucket_s=300, retain=8)
    for st in (a, b, c):
        for grid in st.source.polled:
            ref.add_grid(st.job_id, grid, group="bf16", chips=32)
    roll = daemon.collector.rollup
    assert roll.bucket0 == ref.bucket0
    assert sorted(roll.jobs) == ["a", "b", "c"]
    for jid in ("a", "b", "c"):
        np.testing.assert_array_equal(roll.job_ofu(jid), ref.job_ofu(jid))
    np.testing.assert_array_equal(roll.fleet_stats().mean,
                                  ref.fleet_stats().mean)
    # b stopped polling when removed: 4 rounds of samples, not 8
    assert len(b.source.polled) == 4
    # the published store saw the c join
    assert daemon.store.jobs()["jobs"] == ["a", "b", "c"]


def test_duplicate_add_and_unknown_remove_fail_loudly():
    col = Collector([_sim_stream("a")], _cfg())
    with pytest.raises(ValueError, match="duplicate"):
        col.add_stream(_sim_stream("a", seed=9))
    with pytest.raises(KeyError, match="nope"):
        col.remove_stream("nope")


# ---------------------------------------------------------------------------
# Persistence + restore
# ---------------------------------------------------------------------------
def test_persist_restore_continue_matches_uninterrupted_run(tmp_path):
    path, _ = _archive(tmp_path)
    clk = SimClock()
    straight = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                             clock=clk.monotonic, sleep=clk.sleep)
    straight.run()

    state = str(tmp_path / "state")
    clk = SimClock()
    first = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                          state_dir=state, persist_every=2,
                          clock=clk.monotonic, sleep=clk.sleep)
    first.run(n_rounds=5)
    # "kill -9": no close(); the persist at round 4 is the restart point
    resumed = ServiceDaemon.restore(state, _replay_streams(path), _cfg(),
                                    clock=clk.monotonic, sleep=clk.sleep)
    assert resumed.collector.round_idx == 4
    assert resumed.collector.streams[0].source.cursor_s == 1200.0
    resumed.run()
    resumed.close()

    # every FleetStore answer matches the uninterrupted run
    for query in ("fleet_series", "top_regressions", "goodput"):
        a = getattr(straight.store, query)()
        b = getattr(resumed.store, query)()
        for key in set(a) - {"generation", "round_idx", "clock_s"}:
            assert a[key] == b[key], (query, key)
    ja = straight.store.job_series("traced")
    jb = resumed.store.job_series("traced")
    assert ja["mean"] == jb["mean"] and ja["percentiles"] \
        == jb["percentiles"]
    # alert EPISODES agree (an episode open across the restart re-fires,
    # so round indices may differ — the paged incidents must not)
    assert {(a["job_id"], a["kind"])
            for a in straight.store.alerts()["alerts"]} \
        == {(a["job_id"], a["kind"])
            for a in resumed.store.alerts()["alerts"]}


def test_alert_history_survives_kill9_without_duplicate_pages(tmp_path):
    """ISSUE 8 satellite: alerts fired BEFORE a crash must still be in
    the restored daemon's log, and an episode that was open at the last
    persist must NOT re-page when the restarted detector sees the same
    collapse again — the restarted alert log equals the uninterrupted
    run's exactly."""
    path, _ = _archive(tmp_path)           # regression from t=1800s on
    clk = SimClock()
    straight = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                             clock=clk.monotonic, sleep=clk.sleep)
    straight.run()
    want = straight.collector.alerts
    first_round = min(a.round_idx for a in want
                      if a.kind == "regression")

    state = str(tmp_path / "state")
    clk = SimClock()
    first = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                          state_dir=state, persist_every=1,
                          clock=clk.monotonic, sleep=clk.sleep)
    # run PAST the first regression page, then kill -9 (no close():
    # persist_every=1 made every completed round a restart point)
    first.run(n_rounds=first_round + 2)
    assert any(a.kind == "regression" for a in first.collector.alerts)

    resumed = ServiceDaemon.restore(state, _replay_streams(path), _cfg(),
                                    clock=clk.monotonic, sleep=clk.sleep)
    # the pre-crash log is already there at restore time
    assert [(a.round_idx, a.job_id, a.kind, a.message)
            for a in resumed.collector.alerts] \
        == [(a.round_idx, a.job_id, a.kind, a.message)
            for a in first.collector.alerts]
    resumed.run()
    resumed.close()
    # ...and the finished log matches the uninterrupted run alert for
    # alert: nothing lost, nothing paged twice
    assert [(a.round_idx, a.job_id, a.kind, a.message) for a in want] \
        == [(a.round_idx, a.job_id, a.kind, a.message)
            for a in resumed.collector.alerts]
    # the HTTP-facing store agrees
    assert straight.store.alerts()["alerts"] \
        == resumed.store.alerts()["alerts"]


def test_collector_alert_state_roundtrip():
    """Collector-level: alert_state()/restore_alert_state() round-trip
    the log (NaN factors included) and the open-episode hysteresis."""
    src = Collector([_sim_stream("a", duration_s=600)], _cfg())
    src.alerts = [
        Alert(3, 900.0, "a", "regression", "2.5x collapse", factor=2.5),
        Alert(4, 1200.0, "a", "divergence", "audit", factor=float("nan")),
    ]
    src.deduper._active = {("a", "regression"): [[7, 0]],
                           ("a", "divergence"): [[None, 1]]}
    state = json.loads(json.dumps(src.alert_state()))  # JSON-safe
    dst = Collector([_sim_stream("a", duration_s=600)], _cfg())
    dst.restore_alert_state(state)
    assert [(a.round_idx, a.t_s, a.job_id, a.kind, a.message)
            for a in dst.alerts] \
        == [(a.round_idx, a.t_s, a.job_id, a.kind, a.message)
            for a in src.alerts]
    assert dst.alerts[0].factor == 2.5
    assert np.isnan(dst.alerts[1].factor)
    assert dst.deduper._active == src.deduper._active


def test_restore_rejects_missing_state_and_unseekable_sources(tmp_path):
    with pytest.raises(ValueError, match="no daemon state"):
        ServiceDaemon.restore(str(tmp_path / "empty"), [], _cfg())
    path, _ = _archive(tmp_path)
    state = str(tmp_path / "state")
    clk = SimClock()
    daemon = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                           state_dir=state, persist_every=1,
                           clock=clk.monotonic, sleep=clk.sleep)
    daemon.run(n_rounds=2)
    daemon.close()
    with pytest.raises(ValueError, match="cannot seek"):
        ServiceDaemon.restore(state, [_sim_stream("traced")], _cfg())


def test_fleet_collector_daemon_serves_but_rejects_persist_and_tee(tmp_path):
    from repro.fleet.collector import FleetCollector

    def host(jid, seed):
        return Collector([_sim_stream(jid, seed=seed, duration_s=1200)],
                         _cfg())

    fc = FleetCollector([host("a", 1), host("b", 2)], reduce_every=1)
    with pytest.raises(ValueError, match="plain Collector"):
        ServiceDaemon(fc, state_dir=str(tmp_path), persist_every=1)
    clk = SimClock()
    daemon = ServiceDaemon(FleetCollector([host("a", 1), host("b", 2)],
                                          reduce_every=1),
                           clock=clk.monotonic, sleep=clk.sleep)
    with pytest.raises(ValueError, match="plain Collector"):
        daemon.request_add_stream(_sim_stream("c"))
    daemon.run()
    assert daemon.store.jobs()["jobs"] == ["a", "b"]
    assert clk.sleeps          # fleet daemon paces too


# ---------------------------------------------------------------------------
# Recording tee (the ROADMAP recording-Collector mode), crash-safe
# ---------------------------------------------------------------------------
def test_tee_records_exact_replayable_archives(tmp_path):
    path, grid = _archive(tmp_path)
    tee = str(tmp_path / "tee")
    clk = SimClock()
    daemon = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                           tee_dir=tee, tee_chunk_samples=32,
                           clock=clk.monotonic, sleep=clk.sleep)
    daemon.run()
    daemon.close()
    back = read_trace(os.path.join(tee, "traced.ctr"))
    np.testing.assert_array_equal(back.tpa,
                                  grid.tpa.astype(back.tpa.dtype))
    np.testing.assert_array_equal(back.clock_mhz,
                                  grid.clock_mhz.astype(back.tpa.dtype))
    assert back.t0_s == 0.0 and back.interval_s == 30.0


def test_killed_tee_leaves_replayable_archive_and_restore_completes_it(
        tmp_path):
    """The satellite case: kill the daemon mid-run.  The archive must be
    valid and replayable up to the last persistence point, and a
    restored daemon must continue it into the full exact trace (skipping
    whatever a mid-flight chunk flush already archived)."""
    path, grid = _archive(tmp_path)
    state, tee = str(tmp_path / "state"), str(tmp_path / "tee")
    clk = SimClock()
    # chunk_samples=10 == one round of samples: round 5's append flushes
    # a chunk on its own, putting the archive AHEAD of the persisted
    # round-4 cursor — the overlap case a real crash can always produce
    daemon = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                           state_dir=state, persist_every=2,
                           tee_dir=tee, tee_chunk_samples=10,
                           clock=clk.monotonic, sleep=clk.sleep)
    daemon.run(n_rounds=5)
    del daemon                               # kill: no close(), no flush

    arch = os.path.join(tee, "traced.ctr")
    partial = read_trace(arch)               # manifest must validate
    assert partial.tpa.shape[1] >= 40        # >= everything persisted
    np.testing.assert_array_equal(
        partial.tpa, grid.tpa[:, :partial.tpa.shape[1]].astype(
            partial.tpa.dtype))

    # the partial archive replays through the normal pipeline
    col = Collector([JobStream("re", TraceReplaySource(arch))],
                    _cfg(retain=12))
    assert sum(r.samples for r in col.run()) == partial.tpa.size

    # restore + finish: the tee continues gaplessly to the exact trace
    resumed = ServiceDaemon.restore(state, _replay_streams(path), _cfg(),
                                    tee_dir=tee, tee_chunk_samples=10,
                                    persist_every=2, clock=clk.monotonic,
                                    sleep=clk.sleep)
    resumed.run()
    resumed.close()
    full = read_trace(arch)
    np.testing.assert_array_equal(full.tpa,
                                  grid.tpa.astype(full.tpa.dtype))


def test_tee_flushes_manifest_at_every_persist(tmp_path):
    path, grid = _archive(tmp_path)
    state, tee = str(tmp_path / "state"), str(tmp_path / "tee")
    clk = SimClock()
    # huge chunks: WITHOUT the persist-point flush nothing would ever
    # reach the manifest before close
    daemon = ServiceDaemon(Collector(_replay_streams(path), _cfg()),
                           state_dir=state, persist_every=3,
                           tee_dir=tee, tee_chunk_samples=100_000,
                           clock=clk.monotonic, sleep=clk.sleep)
    daemon.run(n_rounds=4)
    del daemon                               # kill
    back = read_trace(os.path.join(tee, "traced.ctr"))
    # rounds 1-3 were persisted (and flushed); round 4 died in the buffer
    assert back.tpa.shape[1] == 30
    np.testing.assert_array_equal(back.tpa,
                                  grid.tpa[:, :30].astype(back.tpa.dtype))


def test_daemon_guards(tmp_path):
    col = Collector([_sim_stream("j")], _cfg())
    with pytest.raises(ValueError, match="state_dir"):
        ServiceDaemon(col, persist_every=2)
    with pytest.raises(ValueError, match="persist_every"):
        ServiceDaemon(col, persist_every=-1)
    col.on_grid = lambda st, g: None
    with pytest.raises(ValueError, match="on_grid"):
        ServiceDaemon(col, tee_dir=str(tmp_path / "tee"))


def test_stop_interrupts_real_clock_pacing_sleep():
    # default clock/sleep: stop() must wake the inter-round sleep (the
    # SIGTERM path), not leave the daemon dozing toward a 300 s deadline
    import time

    daemon = ServiceDaemon(
        Collector([_sim_stream("j", duration_s=3600)], _cfg()))
    out = {}

    def run():
        out["reports"] = daemon.run(n_rounds=5)

    t = threading.Thread(target=run)
    t0 = time.monotonic()
    t.start()
    time.sleep(0.3)                   # first round done, daemon asleep
    daemon.stop()
    t.join(timeout=10)
    assert not t.is_alive()
    assert time.monotonic() - t0 < 5.0
    assert 1 <= len(out["reports"]) < 5


def test_empty_publish_reports_null_weighted_ofu_not_zero():
    # before the first round the daemon publishes an empty rollup; the
    # dashboard must read "no data yet" (null), never 0% OFU
    daemon = ServiceDaemon(
        Collector([_sim_stream("j")], _cfg()),
        clock=SimClock().monotonic, sleep=SimClock().sleep)
    fleet = daemon.store.fleet_series()
    assert fleet["generation"] == 1
    assert fleet["weighted_ofu"] is None and fleet["t_s"] == []


def test_tee_rejects_adaptive_retiming_up_front(tmp_path):
    # archives are uniform-cadence; the first retiming would crash the
    # loop mid-round, so the combination must fail at construction
    from repro.fleet.collector import AdaptiveConfig
    col = Collector([_sim_stream("j")],
                    _cfg(adaptive=AdaptiveConfig(min_interval_s=5.0)))
    with pytest.raises(ValueError, match="adaptive"):
        ServiceDaemon(col, tee_dir=str(tmp_path / "tee"))
