"""HTTP serving layer (ISSUE 5 acceptance): a ServiceDaemon over a
recorded archive with stubbed wall-clock pacing, queried CONCURRENTLY
through the stdlib HTTP client while it runs, answers bucketwise
identically to direct `scan_rollup`/`analyze_rollup` readout; repeated
identical queries ride the generation ETag (304); error paths are
honest JSON.
"""
import threading
import time

import numpy as np
import pytest

from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.fleet.engine import simulate_devices
from repro.fleet.regression import scan_rollup
from repro.serve import (FleetAPIError, FleetAPIServer, FleetClient,
                         ServiceDaemon, SimClock)
from repro.telemetry import Event, StepProfile, TraceReplaySource
from repro.telemetry.source import write_trace

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
DETECTOR = {"window": 3, "min_duration": 1}


def _from_json(xs):
    return np.array([np.nan if x is None else x for x in xs], float)


@pytest.fixture()
def served(tmp_path):
    """A daemon over two golden archives (one regressed, one healthy
    with app MFU), served over HTTP; yields (daemon, server, run())."""
    grids = {
        "regressed": simulate_devices(
            PROFILE, duration_s=3600, interval_s=30.0,
            events=[Event(1800, 3600, slowdown=2.5)], n_devices=4,
            seed=21),
        "healthy": simulate_devices(
            PROFILE, duration_s=3600, interval_s=30.0, n_devices=4,
            seed=22),
    }
    streams = []
    for name, grid in grids.items():
        path = str(tmp_path / f"{name}.ctr")
        write_trace(grid, path, chunk_samples=40)
        streams.append(JobStream(
            name, TraceReplaySource(path), chips=128, group="bf16",
            app_mfu=0.38 if name == "healthy" else None))
    clk = SimClock()
    daemon = ServiceDaemon(
        Collector(streams, CollectorConfig(round_s=300, bucket_s=300,
                                           retain=12, detector=DETECTOR)),
        clock=clk.monotonic, sleep=clk.sleep)
    server = FleetAPIServer(daemon.store).start()
    try:
        yield daemon, server
    finally:
        server.stop()
        daemon.close()


def test_end_to_end_concurrent_serving_matches_direct_readout(served):
    daemon, server = served
    poll_errors = []
    gen_lists = [[] for _ in range(3)]   # per-thread: appends stay ordered

    def poller(my_gens):
        client = FleetClient(server.url)
        while not done.is_set():
            try:
                my_gens.append(client.fleet()["generation"])
                client.alerts()
            except Exception as e:      # noqa: BLE001 — collected below
                poll_errors.append(e)

    # deterministic interleaving: a round may not advance until every
    # poller has observed the generation it just published — under
    # SimClock pacing costs no wall time, so free-running pollers could
    # otherwise miss the whole run (the PR-6 flake)
    def gate(_report):
        target = daemon.store.generation
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if all(g and g[-1] >= target for g in gen_lists):
                return
            time.sleep(0.001)

    daemon.on_round = gate
    done = threading.Event()
    threads = [threading.Thread(target=poller, args=(g,))
               for g in gen_lists]
    for t in threads:
        t.start()
    reports = daemon.run()
    done.set()
    for t in threads:
        t.join(timeout=10)
    assert not poll_errors
    assert len(reports) == 12
    # every poller watched the generation advance monotonically across
    # the run: the gate pins its first observation to round 1's publish
    # (gen ≤ 2) and its last at or past round 12's (gen 13)
    for g in gen_lists:
        assert g and g[-1] > g[0]
        assert all(b >= a for a, b in zip(g, g[1:]))

    client = FleetClient(server.url)
    roll = daemon.collector.rollup

    # fleet + job series: bucketwise identical to direct readout
    fleet = client.fleet()
    np.testing.assert_array_equal(_from_json(fleet["mean"]),
                                  roll.fleet_stats().mean)
    for jid in ("regressed", "healthy"):
        job = client.job(jid)
        direct = roll.job_stats(jid)
        np.testing.assert_array_equal(_from_json(job["mean"]), direct.mean)
        np.testing.assert_array_equal(_from_json(job["weight"]),
                                      direct.weight)
        for q in (10, 50, 90):
            np.testing.assert_array_equal(
                _from_json(job["percentiles"][str(q)]),
                direct.percentiles[q])

    # top-k regressions == scan_rollup, absolute anchors
    worst = client.top_regressions(k=5, **DETECTOR)
    direct_regs = scan_rollup(roll, **DETECTOR)
    assert {d["job_id"] for d in worst["regressions"]} \
        == set(direct_regs) == {"regressed"}
    r = direct_regs["regressed"][0]
    assert worst["regressions"][0]["factor"] == pytest.approx(r.factor)
    assert worst["regressions"][0]["start_bucket"] \
        == roll.bucket0 + r.start_idx

    # alerts match the collector's (one regression episode, fired once)
    alerts = client.alerts()
    assert [(a["job_id"], a["kind"]) for a in alerts["alerts"]] \
        == [(a.job_id, a.kind) for a in daemon.collector.alerts]
    assert ["regressed", "regression"] in alerts["active_episodes"]

    # the cache story: identical repeat queries are 304-served
    h0 = client.hits_304
    again = client.fleet()
    assert client.hits_304 == h0 + 1 and again == fleet
    client.job("healthy")
    assert client.hits_304 == h0 + 2
    # the store never recomputed for the 304s
    misses = daemon.store.cache_misses
    client.fleet()
    client.top_regressions(k=5, **DETECTOR)
    assert daemon.store.cache_misses == misses


def test_etag_rolls_over_when_generation_advances(served):
    daemon, server = served
    client = FleetClient(server.url)
    daemon.run(n_rounds=1)
    first = client.fleet()
    assert client.fleet() == first and client.hits_304 == 1
    daemon.run(n_rounds=1)                   # new generation published
    second = client.fleet()
    assert client.hits_304 == 1              # NOT a 304: fresh answer
    assert second["generation"] > first["generation"]
    assert len(second["t_s"]) >= len(first["t_s"])


def test_http_error_paths(served):
    daemon, server = served
    daemon.run(n_rounds=2)
    client = FleetClient(server.url)
    with pytest.raises(FleetAPIError, match="unknown job") as ei:
        client.job("nope")
    assert ei.value.status == 404
    with pytest.raises(FleetAPIError, match="unknown query kind") as ei:
        client.query("frobnicate")
    assert ei.value.status == 400
    with pytest.raises(FleetAPIError, match="API root") as ei:
        client._get("/v2/fleet")
    assert ei.value.status == 404
    with pytest.raises(FleetAPIError, match="percentiles") as ei:
        client.fleet(qs=(120,))
    assert ei.value.status == 400
    with pytest.raises(FleetAPIError, match="not a int") as ei:
        client.query("top_regressions", k="many")
    assert ei.value.status == 400
    with pytest.raises(FleetAPIError, match="limit=0") as ei:
        client.alerts(limit=0)
    assert ei.value.status == 400
    # non-finite numeric params never reach the store (nan would poison
    # cache keys and leak bare-NaN tokens into strict-JSON bodies)
    for bad in ("nan", "inf", "-inf"):
        with pytest.raises(FleetAPIError, match="finite") as ei:
            client.goodput(healthy_ofu=bad)
        assert ei.value.status == 400
    # group series + explicit qs through /v1/query round the API out
    grp = client.query("series", scope="group", id="bf16", qs="25,75")
    assert set(grp["percentiles"]) == {"25", "75"}


def test_etag_carries_boot_nonce_and_never_validates_invalid_paths(served):
    import urllib.error
    import urllib.request

    daemon, server = served
    daemon.run(n_rounds=1)
    gen = daemon.store.generation

    def get(path, inm=None):
        req = urllib.request.Request(server.url + path)
        if inm:
            req.add_header("If-None-Match", inm)
        try:
            with urllib.request.urlopen(req, timeout=10) as resp:
                return resp.status, resp.headers.get("ETag")
        except urllib.error.HTTPError as e:
            return e.code, e.headers.get("ETag")

    status, etag = get("/v1/fleet")
    assert status == 200 and etag == f'"gen-{daemon.store.boot}-{gen}"'
    # a validator from a PREVIOUS server process (same generation count,
    # different boot) must NOT 304 into stale data
    assert get("/v1/fleet", inm=f'"gen-{gen}"')[0] == 200
    assert get("/v1/fleet", inm=f'"gen-deadbeef-{gen}"')[0] == 200
    # the real validator does 304
    assert get("/v1/fleet", inm=etag)[0] == 304
    # ...but never validates an invalid path or param into a 304
    assert get("/v1/nonsense", inm=etag)[0] == 404
    assert get("/v1/fleet?qs=120", inm=etag)[0] == 400


def test_store_cache_is_bounded_under_param_cycling(served):
    daemon, server = served
    daemon.run(n_rounds=1)
    store = daemon.store
    client = FleetClient(server.url)
    for k in range(store.max_cache_entries + 50):
        client.goodput(healthy_ofu=round(0.2 + k * 1e-4, 6))
    assert len(store._cache) <= store.max_cache_entries


def test_jobs_listing_and_divergence_over_http(served):
    daemon, server = served
    daemon.run()
    client = FleetClient(server.url)
    assert client.jobs()["jobs"] == ["healthy", "regressed"]
    assert client.jobs()["groups"] == ["bf16"]
    div = client.divergence()
    assert "r_all" in div or div["flagged"] == []
    gp = client.goodput(healthy_ofu=0.5)
    assert gp["healthy_ofu"] == 0.5
    assert gp["jobs"][0]["job_id"] == "regressed"   # biggest waste pool


def test_dashboard_page_serves_well_formed_html(served):
    import urllib.request

    daemon, server = served
    daemon.run(n_rounds=1)
    for path in ("/dashboard", "/dashboard/"):
        with urllib.request.urlopen(server.url + path,
                                    timeout=10) as resp:
            assert resp.status == 200
            ctype = resp.headers.get("Content-Type", "")
            assert ctype.startswith("text/html")
            body = resp.read().decode()
        assert int(resp.headers["Content-Length"]) == \
            len(body.encode())
    # well-formed enough for a browser: doctype, matched document
    # tags, and the JS actually polls the JSON API it claims to
    assert body.lstrip().startswith("<!DOCTYPE html>")
    for tag in ("html", "head", "body", "script", "svg", "table"):
        assert body.count(f"<{tag}") == body.count(f"</{tag}>"), tag
    assert "/v1/query?kind=series&scope=fleet" in body
    assert "/v1/query?kind=top_regressions" in body
    assert "/v1/alerts" in body
    # the JSON API's path space is untouched by the HTML route
    assert FleetClient(server.url).fleet()["scope"] == "fleet"
