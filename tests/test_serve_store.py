"""FleetStore coverage (ISSUE 5): query answers are bucketwise identical
to direct rollup/detector readout, the generation cache serves repeats
without recomputing, publishes isolate readers from collector mutation,
and every payload is strictly JSON-serializable (no NaN on the wire).
"""
import json
import threading

import numpy as np
import pytest

from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.fleet.divergence import analyze_rollup
from repro.fleet.regression import scan_rollup
from repro.fleet.streaming import (StreamingRollup, WindowedRollup,
                                   weighted_mean)
from repro.serve.store import FleetStore
from repro.telemetry import Event, SimulatorSource, StepProfile

PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)


def _from_json(xs):
    """Payload list (nulls for NaN) back to an array for comparisons."""
    return np.array([np.nan if x is None else x for x in xs], float)


def _collector(duration_s=3600, with_event=True, app_mfu=0.38):
    streams = [
        JobStream("healthy", SimulatorSource(
            PROFILE, duration_s=duration_s, interval_s=30, n_devices=4,
            seed=1), chips=64, group="bf16", app_mfu=app_mfu),
        JobStream("regressing", SimulatorSource(
            PROFILE, duration_s=duration_s, interval_s=30, n_devices=4,
            seed=2, events=[Event(duration_s / 2, duration_s,
                                  slowdown=2.5)] if with_event else ()),
            chips=128, group="fp8"),
    ]
    cfg = CollectorConfig(round_s=300, bucket_s=300, retain=12,
                          detector={"window": 3, "min_duration": 1})
    col = Collector(streams, cfg)
    col.run()
    return col


def test_series_queries_match_direct_rollup_readout():
    col = _collector()
    store = FleetStore()
    store.update_from(col)
    roll = col.rollup

    fleet = store.fleet_series()
    direct = roll.fleet_stats()
    np.testing.assert_array_equal(_from_json(fleet["mean"]), direct.mean)
    np.testing.assert_array_equal(_from_json(fleet["weight"]),
                                  direct.weight)
    np.testing.assert_allclose(_from_json(fleet["t_s"]), direct.centers_s)
    for q in (10, 50, 90):
        np.testing.assert_array_equal(
            _from_json(fleet["percentiles"][str(q)]),
            direct.percentiles[q])
    assert fleet["weighted_ofu"] == pytest.approx(weighted_mean(direct))
    assert fleet["window"] == {"bucket0": roll.bucket0,
                               "end_bucket": roll.end_bucket,
                               "retain": roll.retain}
    at = roll.fleet_alltime()
    assert fleet["alltime"]["mean"] == pytest.approx(at["mean"])
    assert fleet["alltime"]["weight"] == pytest.approx(at["weight"])

    for jid in ("healthy", "regressing"):
        job = store.job_series(jid)
        np.testing.assert_array_equal(_from_json(job["mean"]),
                                      roll.job_stats(jid).mean)
        assert job["scope"] == "job" and job["id"] == jid
    assert store.job_series("healthy")["meta"]["app_mfu"] == 0.38
    assert store.job_series("regressing")["meta"] is None

    grp = store.group_series("fp8")
    np.testing.assert_array_equal(_from_json(grp["mean"]),
                                  roll.group_stats("fp8").mean)


def test_top_regressions_matches_scan_rollup_with_absolute_anchors():
    col = _collector()
    store = FleetStore()
    store.update_from(col)
    worst = store.top_regressions(k=3, window=3, min_duration=1)
    direct = scan_rollup(col.rollup, window=3, min_duration=1)
    assert worst["total"] == sum(len(v) for v in direct.values())
    top = worst["regressions"][0]
    assert top["job_id"] == "regressing"
    r = direct["regressing"][0]
    assert top["factor"] == pytest.approx(r.factor)
    assert top["start_bucket"] == col.rollup.bucket0 + r.start_idx
    assert top["ongoing"] == (r.end_idx is None)
    # ranked hardest-first
    factors = [d["factor"] for d in worst["regressions"]]
    assert factors == sorted(factors, reverse=True)


def test_alerts_and_divergence_queries():
    col = _collector()
    store = FleetStore()
    store.update_from(col)
    al = store.alerts()
    assert al["total"] == len(col.alerts)
    assert [(a["job_id"], a["kind"]) for a in al["alerts"]] \
        == [(a.job_id, a.kind) for a in col.alerts]
    assert al["active_episodes"] == [list(k) for k in col.deduper.active]
    assert store.alerts(limit=1)["alerts"] == al["alerts"][-1:]

    div = store.divergence()
    rep = analyze_rollup(col.rollup, empty_ok=True)
    assert div["r_all"] == pytest.approx(rep.r_all)
    assert [f["job_id"] for f in div["flagged"]] \
        == [p.job_id for p in rep.flagged]


def test_alerts_limit_validated_and_republish_is_incremental():
    col = _collector()
    store = FleetStore()
    store.update_from(col)
    with pytest.raises(ValueError, match="limit=0"):
        store.alerts(limit=0)
    with pytest.raises(ValueError, match="limit=-3"):
        store.alerts(limit=-3)
    # republishing the same append-only alert log reuses the already-
    # converted payload prefix (O(new alerts) per round, not O(all))
    first = store.alerts()["alerts"]
    store.update_from(col)
    second = store.alerts()["alerts"]
    assert len(first) == len(second) > 0
    assert all(a is b for a, b in zip(first, second))


def test_goodput_summary_weights_and_waste_ranking():
    col = _collector()
    store = FleetStore()
    store.update_from(col)
    gp = store.goodput(healthy_ofu=0.40)
    roll = col.rollup
    total_w = sum(roll.job_alltime(j, qs=())["weight"] for j in roll.jobs)
    assert gp["weight"] == pytest.approx(total_w)
    want = sum(roll.job_alltime(j, qs=())["mean"]
               * roll.job_alltime(j, qs=())["weight"]
               for j in roll.jobs) / total_w
    assert gp["weighted_ofu"] == pytest.approx(want)
    # only 'healthy' registered an app MFU
    healthy_w = roll.job_alltime("healthy", qs=())["weight"]
    assert gp["app_mfu_coverage"] == pytest.approx(healthy_w / total_w)
    assert gp["ofu_coverage"] == 1.0
    # the regressed job wastes more of its pool; ranking is waste-desc
    wastes = [j["waste"] for j in gp["jobs"]]
    assert wastes == sorted(wastes, reverse=True)
    assert gp["jobs"][0]["job_id"] == "regressing"


def test_generation_cache_serves_repeats_and_invalidates_on_update():
    col = _collector(duration_s=1200, with_event=False)
    store = FleetStore()
    store.update_from(col)
    g1 = store.generation
    first = store.fleet_series()
    assert store.cache_misses == 1 and store.cache_hits == 0
    assert store.fleet_series() is first        # cached object, not a copy
    assert store.cache_hits == 1
    # different params = different cache key
    store.fleet_series(qs=(50,))
    assert store.cache_misses == 2
    # publish invalidates: same query recomputes at the new generation
    store.update_from(col)
    assert store.generation == g1 + 1
    second = store.fleet_series()
    assert second is not first
    assert second["generation"] == g1 + 1
    assert store.cache_misses == 3


def test_update_copy_isolates_store_from_collector_mutation():
    col = _collector(duration_s=1800, with_event=False)
    store = FleetStore()
    mid = col.rollup.spawn_empty().merge(col.rollup)   # reference answer
    store.update_from(col)
    before = _from_json(store.fleet_series()["mean"]).copy()
    # keep collecting: the live rollup moves on, the store must not
    col.streams[0].source.duration_s = 3600           # extend the run
    col.streams[1].source.duration_s = 3600
    col.run()
    np.testing.assert_array_equal(
        _from_json(store.fleet_series()["mean"]), before)
    np.testing.assert_array_equal(before, mid.fleet_stats().mean)


def test_empty_store_answers_every_query():
    store = FleetStore()
    assert store.fleet_series()["t_s"] == []
    assert store.fleet_series()["weighted_ofu"] is None
    assert store.jobs() == {"jobs": [], "groups": [], "generation": 0,
                            "round_idx": 0, "clock_s": 0.0}
    assert store.top_regressions()["regressions"] == []
    assert store.alerts()["alerts"] == []
    assert store.goodput()["jobs"] == []
    assert store.divergence()["flagged"] == []


def test_unknown_scope_ids_raise_keyerror():
    col = _collector(duration_s=1200, with_event=False)
    store = FleetStore()
    store.update_from(col)
    with pytest.raises(KeyError, match="nope"):
        store.job_series("nope")
    with pytest.raises(KeyError, match="int8"):
        store.group_series("int8")


def test_payloads_are_strict_json():
    # NaN must never reach the wire: a rollup with gap buckets produces
    # NaN means, and json.dumps(allow_nan=False) proves they were cleaned
    roll = WindowedRollup(bucket_s=60, retain=10)
    t = np.array([30.0, 90.0, 570.0])          # buckets 0, 1, then a gap
    roll.observe("gappy", t, np.array([0.4, 0.5, 0.3]))
    store = FleetStore()
    store.update(roll, round_idx=1, clock_s=600.0)
    for payload in (store.fleet_series(), store.job_series("gappy"),
                    store.jobs(), store.top_regressions(),
                    store.alerts(), store.goodput(), store.divergence()):
        json.dumps(payload, allow_nan=False)
    assert None in store.job_series("gappy")["mean"]   # the gap, as null


def test_update_from_fleet_collector_serves_reduced_state():
    from repro.fleet.collector import FleetCollector

    def host(jid, seed):
        src = SimulatorSource(PROFILE, duration_s=1800, interval_s=30,
                              n_devices=2, seed=seed)
        return Collector([JobStream(jid, src, chips=32)],
                         CollectorConfig(round_s=300, retain=6))

    fc = FleetCollector([host("a", 1), host("b", 2)], reduce_every=1)
    fc.run()
    store = FleetStore()
    store.update_from(fc)
    assert store.jobs()["jobs"] == ["a", "b"]
    np.testing.assert_array_equal(
        _from_json(store.fleet_series()["mean"]),
        fc.fleet.fleet_stats().mean)


def test_plain_rollup_publishes_without_window():
    roll = StreamingRollup(bucket_s=60)
    roll.observe("j", np.arange(1, 601, dtype=float),
                 np.full(600, 0.4))
    store = FleetStore()
    store.update(roll)
    fleet = store.fleet_series()
    assert "window" not in fleet and "alltime" not in fleet
    gp = store.goodput()
    assert gp["jobs"][0]["ofu"] == pytest.approx(0.4)


def test_stats_readout_never_mutates_shared_state():
    """Regression (ISSUE 6): _stats used to pad lazily-grown scopes by
    reassigning the SHARED per-scope arrays, so a read-only job_stats()
    resized rollup internals — a data race for HTTP readers sharing one
    published snapshot. Reads must pad locally."""
    roll = StreamingRollup(bucket_s=10)
    roll.observe("a", np.array([5.0]), np.array([0.4]), group="bf16")
    roll.observe("b", np.array([95.0]), np.array([0.5]), group="bf16")
    h_a = roll._hists[("job", "a")]
    s_a = roll._sums[("job", "a")]
    st = roll.job_stats("a")                 # short scope: needs padding
    assert len(st.mean) == roll.n_buckets == 10
    assert st.mean[0] == pytest.approx(0.4)
    assert np.isnan(st.mean[1:]).all()
    # ...but the rollup's own arrays were never resized or reassigned
    assert roll._hists[("job", "a")] is h_a and h_a.shape[0] == 1
    assert roll._sums[("job", "a")] is s_a and s_a.shape[0] == 1


def test_concurrent_readout_hammer_on_published_rollup():
    """Many reader threads hammering job/fleet stats on one shared
    rollup (the FleetStore publish model) agree with the single-threaded
    answer and never error — pins the _stats local-pad fix."""
    roll = WindowedRollup(bucket_s=10, retain=50)
    roll.observe("early", np.array([5.0, 15.0]), np.array([0.4, 0.5]),
                 group="bf16")
    for k in range(40):                       # grow well past "early"
        roll.observe("late", np.array([5.0 + 10 * k]), np.array([0.3]),
                     group="bf16")
    ref_job = roll.job_stats("early")
    ref_fleet = roll.fleet_stats()
    errors = []

    def reader():
        try:
            for _ in range(200):
                st = roll.job_stats("early")
                np.testing.assert_array_equal(st.mean, ref_job.mean)
                np.testing.assert_array_equal(st.weight, ref_job.weight)
                np.testing.assert_array_equal(roll.fleet_stats().weight,
                                              ref_fleet.weight)
        except Exception as e:                # noqa: BLE001 — collected
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors
    assert roll._hists[("job", "early")].shape[0] < roll.n_buckets
