"""System-level behaviour: sharding rules, HLO analysis, serve loop,
MoE routing invariants, end-to-end OFU pipeline sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.configs import SHAPES, get_config, input_specs
from repro.launch.hlo_analysis import analyze, multiplicities, parse_module

# ---------------------------------------------------------------------------
# sharding rules (AbstractMesh: no devices needed)
# ---------------------------------------------------------------------------
try:
    AM = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
except TypeError:
    try:  # jax ~0.4.3x: a single tuple of (name, size) pairs
        AM = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:  # older keyword signature
        AM = jax.sharding.AbstractMesh(axis_sizes=(16, 16),
                                       axis_names=("data", "model"))


def _spec(path, shape):
    from repro.launch.sharding import param_spec
    return param_spec(path, shape, AM, ("data",), "model")


def test_param_specs_core_rules():
    P = jax.sharding.PartitionSpec
    # column-parallel: tp on last dim, fsdp on the contracting dim
    assert _spec("['layers']['attn']['wq']", (32, 2048, 4096)) \
        == P(None, "data", "model")
    # row-parallel: tp on contracting dim
    assert _spec("['layers']['attn']['wo']", (32, 4096, 2048)) \
        == P(None, "model", "data")
    # expert-parallel: tp on the expert dim
    assert _spec("['moe_layers']['mlp']['experts']['wi']",
                 (58, 256, 7168, 2048)) == P(None, "model", "data", None)
    # vocab-parallel embed
    assert _spec("['embed']", (128256, 4096)) == P("model", "data")
    # divisibility guard: a 50-wide dim must stay unsharded
    assert _spec("['layers']['attn']['wq']", (12, 50, 50)) == P(None, None,
                                                                None)
    # optimizer moments inherit the parameter rule
    assert _spec("['mu']['layers']['attn']['wq']['m']", (32, 2048, 4096)) \
        == P(None, "data", "model")
    # factored moment rows (dim dropped) stay in range
    assert _spec("['mu']['layers']['attn']['wq']['v']['row']", (32, 2048)) \
        is not None


def test_batch_shardings_cover_all_inputs():
    from repro.launch.sharding import batch_shardings
    for arch in ("qwen3-4b", "deepseek-v3-671b", "mamba2-780m", "zamba2-7b",
                 "whisper-small", "phi-3-vision-4.2b"):
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if not cfg.supports_shape(shape):
                continue
            sh = batch_shardings(cfg, shape, AM, ("data",), "model")
            specs = input_specs(cfg, shape)
            assert set(sh) == set(specs), (arch, sname)
            # every sharded dim must divide the axis
            for k, ns in sh.items():
                dims = specs[k].shape
                for i, ax in enumerate(ns.spec):
                    if ax is None or i >= len(dims):
                        continue
                    size = AM.shape[ax] if isinstance(ax, str) else \
                        int(np.prod([AM.shape[a] for a in ax]))
                    assert dims[i] % size == 0, (arch, sname, k, i)


# ---------------------------------------------------------------------------
# serving-mode sharding (§Perf cell B: EP² + no-FSDP decode layout)
# ---------------------------------------------------------------------------
def test_serving_param_specs_ep2():
    from repro.launch.sharding import param_spec
    P = jax.sharding.PartitionSpec
    # v3 experts (58, 256, 7168, 2048): EP over the FULL mesh when serving
    s = param_spec("['moe_layers']['mlp']['experts']['wi']",
                   (58, 256, 7168, 2048), AM, ("data",), "model",
                   fsdp=False, serving=True)
    assert s == P(None, ("data", "model"), None, None)
    # 64 experts don't divide 256 -> divisibility guard falls back to tp
    s = param_spec("['moe_layers']['mlp']['experts']['wi']",
                   (27, 64, 2048, 1408), AM, ("data",), "model",
                   fsdp=False, serving=True)
    assert s == P(None, "model", None, None)
    # non-expert weights: TP only, replicated over data (no FSDP gathers)
    s = param_spec("['dense_layers']['attn']['wq']", (61, 7168, 24576),
                   AM, ("data",), "model", fsdp=False, serving=True)
    assert s == P(None, None, "model")


def test_shardctx_ep_resolution():
    from repro.models.common import ShardCtx
    ctx = ShardCtx(mesh=AM, dp=("data",), tp="model",
                   ep=("data", "model"))
    assert ctx.ep_covers_dp
    assert ctx.spec("ep").spec == jax.sharding.PartitionSpec(
        ("data", "model"))
    ctx2 = ShardCtx(mesh=AM, dp=("data",), tp="model")
    assert not ctx2.ep_covers_dp
    assert ctx2.ep_axes == "model"


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------
_FAKE_HLO = """\
HloModule test

%loop_cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %limit = s32[] constant(10)
  ROOT %cmp = pred[] compare(%iv, %limit), direction=LT
}

%loop_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum
  %iv = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%iv, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (in: f32[8,8]) -> (s32[], f32[8,8]) {
  %in = f32[8,8] parameter(0)
  %c = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%c, %in)
  ROOT %w = (s32[], f32[8,8]) while(%init), condition=%loop_cond, body=%loop_body
}
"""


def test_hlo_trip_count_and_flops():
    st_ = analyze(_FAKE_HLO, 4)
    # dot: 2*8*8*8 = 1024 flops, x10 trips
    assert st_.flops == pytest.approx(10 * 1024)
    # all-reduce: 8*8*4B * 2 * (3/4) wire bytes, x10
    assert st_.collective_bytes["all-reduce"] == pytest.approx(
        10 * 256 * 2 * 0.75)
    assert st_.collective_counts["all-reduce"] == 10


def test_hlo_multiplicities():
    mod = parse_module(_FAKE_HLO)
    mult = multiplicities(mod)
    assert mult[mod.entry] == 1.0
    assert mult["loop_body"] == 10.0
    assert mult["loop_cond"] == 11.0


# ---------------------------------------------------------------------------
# serve loop: multi-step decode consistency (integration)
# ---------------------------------------------------------------------------
def test_serve_loop_runs_all_families():
    from repro.launch.serve import init_caches
    from repro.train.steps import make_serve_step
    from repro.models import init_params
    for arch in ("granite-3-2b", "mamba2-780m", "deepseek-v3-671b"):
        cfg = get_config(arch).smoke()
        params = init_params(cfg, jax.random.key(0))
        serve = jax.jit(make_serve_step(cfg))
        B, S = 2, 16
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
                 "cache_index": jnp.asarray(0, jnp.int32),
                 **init_caches(cfg, B, S)}
        for i in range(4):
            nxt, caches = serve(params, batch)
            assert nxt.shape == (B, 1)
            assert (np.asarray(nxt) >= 0).all()
            assert (np.asarray(nxt) < cfg.vocab_size).all()
            batch = {"tokens": nxt.astype(jnp.int32),
                     "cache_index": jnp.asarray(i + 1, jnp.int32), **caches}


# ---------------------------------------------------------------------------
# MoE routing invariants (property-based)
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_moe_routing_finite_and_balanced(seed):
    from repro.models.moe import moe_apply, moe_init
    cfg = get_config("deepseek-moe-16b").smoke()
    rng = np.random.default_rng(seed)
    p = moe_init(jax.random.key(seed % 1000), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.5,
                    jnp.float32)
    y, aux = moe_apply(cfg, p, x, None, router_stats=True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.9  # load-balance loss >= ~1 at uniform


def test_moe_decode_single_group_matches_batched():
    """The one-group decode routing (§Perf B2) must be numerically
    identical to routing the same tokens as a (1, B) sequence."""
    from repro.models.moe import moe_apply, moe_init
    cfg = get_config("deepseek-moe-16b").smoke()
    rng = np.random.default_rng(0)
    p = moe_init(jax.random.key(3), cfg, jnp.float32)
    xb = jnp.asarray(rng.standard_normal((8, 1, cfg.d_model)) * 0.5,
                     jnp.float32)
    y_dec = moe_apply(cfg, p, xb, None)           # (B,1,d) path
    y_seq = moe_apply(cfg, p, xb.reshape(1, 8, -1), None)
    np.testing.assert_allclose(np.asarray(y_dec).reshape(8, -1),
                               np.asarray(y_seq)[0], rtol=1e-5, atol=1e-5)


def test_ofu_end_to_end_pipeline():
    """counters -> scrape -> job OFU -> divergence: the full §V loop."""
    from repro.fleet import JobSpec, simulate_job
    from repro.fleet.divergence import JobPoint, analyze as fleet_analyze
    jobs = []
    rng = np.random.default_rng(1)
    for i in range(12):
        arch = ["qwen3-4b", "granite-3-2b", "llama3.2-3b"][i % 3]
        t = simulate_job(JobSpec(f"j{i}", arch, chips=64,
                                 true_duty=float(rng.uniform(0.2, 0.5)),
                                 duration_s=120, seed=i), max_devices=1)
        jobs.append(JobPoint(f"j{i}", arch, 64, t.app_mfu, t.ofu))
    rep = fleet_analyze(jobs)
    assert rep.r_all > 0.95  # healthy fleet: tight correlation
    assert rep.mae_all < 0.05
