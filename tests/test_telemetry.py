"""Telemetry substrate: clock process, hardware averaging, scrape rules,
event injection (the §VI-A regression mechanics)."""
import warnings

import numpy as np
import pytest

from repro.telemetry import (MAX_HW_AVG_WINDOW_S, ClockModel, Event,
                             ScrapeSeries, SimulatedDeviceBackend,
                             StepProfile, scrape)


def _profile(duty=0.4, step_s=2.0):
    return StepProfile(mxu_time_s=duty * step_s, step_time_s=step_s)


def test_clock_process_statistics():
    cm = ClockModel()
    duty = np.full(3000, 1.0)
    f = cm.simulate(duty, dt_s=1.0, seed=0)
    # paper §IV-C: sustained load -> throttled mean, σ ~ 32 MHz
    assert abs(f.mean() - cm.mean_clock(1.0)) < 20
    assert 15 < f.std() < 60
    assert f.max() <= cm.chip.f_max_mhz + 1e-6


def test_tpa_is_hardware_averaged():
    be = SimulatedDeviceBackend(_profile(0.4), seed=1)
    tpa, clk = be.poll(30.0)
    assert tpa == pytest.approx(0.4, abs=0.02)
    assert clk <= be.chip.f_max_mhz


def test_scrape_interval_rule():
    be = SimulatedDeviceBackend(_profile(), seed=0)
    with pytest.raises(ValueError):
        scrape(be, 120.0, 60.0)          # > 30 s window -> avg-of-avgs
    s = scrape(be, 120.0, 30.0)
    assert len(s.tpa) == 4


def test_event_injection_reproduces_regression_factor():
    """A 2.5x host-sync slowdown must show as exactly ~2.5x lower TPA
    (the Gloo debug-flag case, Fig. 6)."""
    ev = Event(start_s=300, end_s=900, slowdown=2.5)
    be = SimulatedDeviceBackend(_profile(0.45), events=[ev], seed=2)
    s = scrape(be, 900.0, 30.0)
    before = s.tpa[:10].mean()
    during = s.tpa[10:].mean()
    assert before / during == pytest.approx(2.5, rel=0.05)


def test_straggler_scales_step_time():
    a = SimulatedDeviceBackend(_profile(0.4), seed=0).poll(30)[0]
    b = SimulatedDeviceBackend(_profile(0.4), straggler_factor=2.0,
                               seed=0).poll(30)[0]
    assert b == pytest.approx(a / 2, rel=0.05)


def test_subsample_matches_table1_semantics():
    s = ScrapeSeries(1.0, np.arange(60, dtype=float), np.arange(60.0))
    s30 = s.subsample(30)
    assert s30.interval_s == 30.0
    assert len(s30.tpa) == 2
    assert s30.tpa[0] == 29  # last point of each window (point sample)


def test_nonstrict_scrape_warns_and_degrades():
    """§IV-C average-of-averages hazard: polling slower than the 30 s
    hardware window is allowed with strict=False but (a) warns, and (b)
    each reading reflects ONLY the trailing 30 s — activity in the blind
    leading part of the interval is invisible."""
    # duty collapses in [0, 30) only: a 60 s poll's blind zone
    ev = Event(start_s=0.0, end_s=30.0, slowdown=10.0)
    be = SimulatedDeviceBackend(_profile(0.4), events=[ev], seed=4)
    with pytest.warns(RuntimeWarning, match="average-of-averages"):
        s = scrape(be, 60.0, 60.0, strict=False)
    assert s.interval_s == 60.0 and len(s.tpa) == 1
    # the collapse happened entirely inside the blind window: unseen
    assert s.tpa[0] == pytest.approx(0.4, abs=0.02)
    # the same collapse IS visible at a compliant 30 s interval
    be2 = SimulatedDeviceBackend(_profile(0.4), events=[ev], seed=4)
    s2 = scrape(be2, 60.0, 30.0)
    assert s2.tpa[0] == pytest.approx(0.04, abs=0.01)
    # fast intervals never warn
    be3 = SimulatedDeviceBackend(_profile(0.4), seed=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        scrape(be3, 60.0, 30.0)


def test_subsample_alignment():
    """Table I methodology: subsample(k) must keep the LAST reading of
    every k-window (point-sample semantics), drop the ragged tail, and
    compose multiplicatively."""
    n = 61                      # deliberately not a multiple of k
    s = ScrapeSeries(2.0, np.arange(n, dtype=float), 1000.0 + np.arange(n))
    s5 = s.subsample(5)
    assert s5.interval_s == 10.0
    assert len(s5.tpa) == len(s5.clock_mhz) == 12
    np.testing.assert_array_equal(s5.tpa, np.arange(4, n - 1, 5))
    # clock stays aligned with tpa sample-for-sample
    np.testing.assert_array_equal(s5.clock_mhz - 1000.0, s5.tpa)
    # two-stage 2x3 equals the matching slice of the 1x6 subsample
    s6a = s.subsample(2).subsample(3)
    s6b = s.subsample(6)
    assert s6a.interval_s == s6b.interval_s == 12.0
    np.testing.assert_array_equal(s6a.tpa[:len(s6b.tpa)], s6b.tpa)


def test_clock_sampling_noise_shrinks_with_interval():
    """Table I: coarser intervals -> larger deviation from the 1 s baseline,
    but 95% CI stays small (sub-pp) for steady workloads."""
    be = SimulatedDeviceBackend(_profile(0.55, 1.0), seed=3)
    base = scrape(be, 1500.0, 1.0)
    ofu_base = (base.tpa * base.clock_mhz).mean() / be.chip.f_max_mhz
    errs = {}
    for k in (5, 30):
        sub = base.subsample(k)
        errs[k] = abs((sub.tpa * sub.clock_mhz).mean()
                      / be.chip.f_max_mhz - ofu_base)
    assert errs[5] <= errs[30] + 0.004
    assert errs[30] < 0.01  # well under 1pp
