"""TelemetrySource abstraction: simulator/backend/replay sources all emit
the same DeviceGrid, traces round-trip exactly through CSV and JSONL, and
a recorded trace drives the full rollup + detector pipeline with no
simulator (engine/jobs) import."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.fleet.engine import simulate_devices
from repro.fleet.regression import detect_regressions, scan_rollup
from repro.fleet.streaming import StreamingRollup
from repro.telemetry import (BackendSource, DeviceGrid, Event,
                             SimulatedDeviceBackend, SimulatorSource,
                             StepProfile, TraceReplaySource, read_trace,
                             scrape, write_trace)

PROF = StepProfile(mxu_time_s=0.8, step_time_s=2.0)


def test_simulator_source_matches_engine():
    src = SimulatorSource(PROF, duration_s=600, interval_s=30.0,
                          n_devices=4, seed=3)
    grid = src.scrapes()
    ref = simulate_devices(PROF, duration_s=600, interval_s=30.0,
                           n_devices=4, seed=3)
    assert isinstance(grid, DeviceGrid)
    np.testing.assert_array_equal(grid.tpa, ref.tpa)
    np.testing.assert_array_equal(grid.clock_mhz, ref.clock_mhz)


def test_sources_enforce_scrape_interval_identically():
    """Interchangeable sources, one §IV-C policy: both reject an
    average-of-averages interval by default; strict=False degrades."""
    sim = SimulatorSource(PROF, duration_s=120, interval_s=60.0,
                          n_devices=1, seed=0)
    be = BackendSource([SimulatedDeviceBackend(PROF, seed=0)],
                       duration_s=120, interval_s=60.0)
    for src in (sim, be):
        with pytest.raises(ValueError, match="average-of-averages"):
            src.scrapes()
    sim.strict = be.strict = False
    for src in (sim, be):
        with pytest.warns(RuntimeWarning, match="average-of-averages"):
            assert src.scrapes().tpa.shape == (1, 2)


def test_series_roundtrip_preserves_t0():
    grid = simulate_devices(PROF, duration_s=300, interval_s=30.0,
                            n_devices=2, seed=0)
    shifted = DeviceGrid(grid.interval_s, grid.tpa, grid.clock_mhz,
                         t0_s=900.0)
    s = shifted.series(1)
    assert s.t0_s == 900.0 and s.subsample(2).t0_s == 900.0
    back = DeviceGrid.from_series(shifted.to_series_list())
    assert back.t0_s == 900.0
    np.testing.assert_allclose(back.times_s, shifted.times_s)


def test_backend_source_matches_scalar_scrape():
    src = BackendSource([SimulatedDeviceBackend(PROF, seed=s)
                         for s in (1, 2)], duration_s=300, interval_s=30.0)
    grid = src.scrapes()
    assert grid.n_devices == 2 and grid.tpa.shape == (2, 10)
    ref = scrape(SimulatedDeviceBackend(PROF, seed=1), 300, 30.0)
    np.testing.assert_array_equal(grid.tpa[0], ref.tpa)
    np.testing.assert_array_equal(grid.clock_mhz[0], ref.clock_mhz)


def test_grid_series_stack_roundtrip():
    grid = simulate_devices(PROF, duration_s=300, interval_s=30.0,
                            n_devices=3, seed=0)
    back = DeviceGrid.from_series(grid.to_series_list())
    np.testing.assert_array_equal(back.tpa, grid.tpa)
    assert back.interval_s == grid.interval_s
    with pytest.raises(ValueError, match="misaligned"):
        DeviceGrid.from_series([grid.series(0),
                                grid.series(1).subsample(2)])


@pytest.mark.parametrize("fmt,suffix", [("csv", ".csv"), ("jsonl", ".jsonl")])
def test_trace_roundtrip_exact(tmp_path, fmt, suffix):
    grid = simulate_devices(PROF, duration_s=600, interval_s=30.0,
                            events=[Event(200, 400, slowdown=2.0)],
                            n_devices=3, seed=7)
    path = str(tmp_path / f"trace{suffix}")
    write_trace(grid, path)                      # fmt inferred from suffix
    replay = TraceReplaySource(path).scrapes()
    assert replay.interval_s == grid.interval_s
    np.testing.assert_array_equal(replay.tpa, grid.tpa)
    np.testing.assert_array_equal(replay.clock_mhz, grid.clock_mhz)
    # explicit fmt agrees with inference
    explicit = read_trace(path, fmt=fmt)
    np.testing.assert_array_equal(explicit.tpa, grid.tpa)


def test_trace_format_validation(tmp_path):
    grid = simulate_devices(PROF, duration_s=60, interval_s=30.0, seed=0)
    with pytest.raises(ValueError, match="cannot infer"):
        write_trace(grid, str(tmp_path / "trace.parquet"))
    with pytest.raises(ValueError, match="unknown trace format"):
        write_trace(grid, str(tmp_path / "t.csv"), fmt="xml")
    # ragged trace (device 1 missing one poll) is rejected
    p = tmp_path / "ragged.csv"
    p.write_text("t_s,device,tpa,clock_mhz\n"
                 "30.0,0,0.4,1300.0\n60.0,0,0.4,1300.0\n"
                 "30.0,1,0.4,1300.0\n")
    with pytest.raises(ValueError, match="ragged"):
        read_trace(str(p))
    # empty trace -> empty grid
    q = tmp_path / "empty.jsonl"
    q.write_text("")
    assert read_trace(str(q)).n_devices == 0
    # a single poll instant cannot pin down the interval: explicit only
    one = tmp_path / "one.csv"
    one.write_text("t_s,device,tpa,clock_mhz\n630.0,0,0.4,1300.0\n")
    with pytest.raises(ValueError, match="single poll instant"):
        read_trace(str(one))
    g1 = TraceReplaySource(str(one), interval_s=30.0).scrapes()
    assert g1.interval_s == 30.0 and g1.times_s[0] == pytest.approx(630.0)


def test_read_trace_rejects_malformed_files(tmp_path):
    """fmt='auto' sniffing must fail LOUD: every malformed-input mode
    gets a clear error naming the offending line, never a silently
    mis-parsed grid (regression tests for the former failure modes)."""
    # headerless CSV: first row is data — skipping it used to drop one
    # poll per device and shift the inferred t0
    p = tmp_path / "headerless.csv"
    p.write_text("30.0,0,0.4,1300.0\n60.0,0,0.41,1310.0\n")
    with pytest.raises(ValueError, match="no header row"):
        read_trace(str(p))
    # header present but a data row is truncated
    p = tmp_path / "truncated.csv"
    p.write_text("t_s,device,tpa,clock_mhz\n30.0,0,0.4,1300.0\n60.0,0\n")
    with pytest.raises(ValueError, match="line 3: truncated row"):
        read_trace(str(p))
    # unparseable cell
    p = tmp_path / "badval.csv"
    p.write_text("t_s,device,tpa,clock_mhz\n30.0,zero,0.4,1300.0\n")
    with pytest.raises(ValueError, match="line 2: malformed value"):
        read_trace(str(p))
    # invalid JSON line
    p = tmp_path / "bad.jsonl"
    p.write_text('{"t_s": 30.0, "device": 0, "tpa": 0.4, '
                 '"clock_mhz": 1300.0}\n{oops\n')
    with pytest.raises(ValueError, match="line 2: invalid JSON"):
        read_trace(str(p))
    # a whole-file JSON array is not JSONL
    p = tmp_path / "array.json"
    p.write_text('[{"t_s": 30.0, "device": 0, "tpa": 0.4, '
                 '"clock_mhz": 1300.0}]\n')
    with pytest.raises(ValueError, match="not a JSONL trace"):
        read_trace(str(p))
    # JSONL record missing a key
    p = tmp_path / "missing.jsonl"
    p.write_text('{"t_s": 30.0, "device": 0, "tpa": 0.4}\n')
    with pytest.raises(ValueError, match=r"missing key\(s\) \['clock_mhz'\]"):
        read_trace(str(p))
    # JSONL value of the wrong type
    p = tmp_path / "badtype.jsonl"
    p.write_text('{"t_s": 30.0, "device": 0, "tpa": [0.4], '
                 '"clock_mhz": 1300.0}\n')
    with pytest.raises(ValueError, match="line 1: malformed value"):
        read_trace(str(p))
    # a directory that isn't a columnar archive
    with pytest.raises(ValueError, match="not a columnar trace archive"):
        read_trace(str(tmp_path))


def test_trace_tolerates_per_device_timestamp_jitter(tmp_path):
    """Real pollers stamp devices a few ms apart; alignment is by poll
    rank, not exact float time equality."""
    p = tmp_path / "jitter.csv"
    p.write_text("t_s,device,tpa,clock_mhz\n"
                 "30.001,0,0.40,1300.0\n60.002,0,0.41,1310.0\n"
                 "30.003,1,0.42,1320.0\n59.999,1,0.43,1330.0\n")
    grid = read_trace(str(p))
    assert grid.tpa.shape == (2, 2)
    np.testing.assert_allclose(grid.tpa, [[0.40, 0.41], [0.42, 0.43]])
    assert grid.interval_s == pytest.approx(30.0, abs=0.01)


def test_midrun_trace_replays_at_recorded_times(tmp_path):
    """A trace sliced from the middle of a run must keep its clock: the
    replayed samples land in the rollup buckets they were recorded in."""
    from repro.fleet.streaming import StreamingRollup
    from repro.telemetry.scrape import DeviceGrid

    grid = simulate_devices(PROF, duration_s=600, interval_s=30.0,
                            n_devices=2, seed=1)
    shifted = DeviceGrid(grid.interval_s, grid.tpa, grid.clock_mhz,
                         t0_s=600.0)                 # second 10 minutes
    assert shifted.times_s[0] == pytest.approx(630.0)
    path = str(tmp_path / "midrun.csv")
    write_trace(shifted, path)
    replay = read_trace(path)
    np.testing.assert_allclose(replay.times_s, shifted.times_s)
    np.testing.assert_array_equal(replay.tpa, shifted.tpa)
    roll = StreamingRollup(bucket_s=300)
    roll.add_grid("midrun", replay)
    stats = roll.job_stats("midrun", qs=())
    assert len(stats.mean) == 4                      # buckets 0-4 spanned
    assert np.isnan(stats.mean[:2]).all()            # nothing before 600 s
    assert np.isfinite(stats.mean[2:]).all()


def test_replay_through_rollup_and_detectors(tmp_path):
    """A recorded regression survives the disk round-trip: the replayed
    trace trips the same detector the simulated grid does."""
    grid = simulate_devices(PROF, duration_s=3600, interval_s=30.0,
                            events=[Event(1800, 3600, slowdown=2.5)],
                            n_devices=4, seed=11)
    path = str(tmp_path / "regressed.jsonl")
    write_trace(grid, path)
    roll = StreamingRollup(bucket_s=120)
    roll.add_grid("replayed", TraceReplaySource(path).scrapes(),
                  group="bf16", chips=256, app_mfu=0.38)
    found = scan_rollup(roll, factor_threshold=1.5)
    assert list(found) == ["replayed"]
    assert 2.0 < found["replayed"][0].factor < 2.6
    # and the bridge to divergence carries the trace-supplied app MFU
    (pt,) = roll.to_job_points()
    assert pt.mfu == 0.38 and pt.chips == 256


def test_replay_pipeline_needs_no_simulator(tmp_path):
    """End-to-end acceptance: trace -> rollup -> regression + divergence in
    a fresh interpreter that never imports the simulator (engine/jobs)."""
    grid = simulate_devices(PROF, duration_s=3600, interval_s=30.0,
                            events=[Event(1800, 3600, slowdown=2.5)],
                            n_devices=2, seed=5)
    path = tmp_path / "trace.csv"
    write_trace(grid, str(path))
    script = f"""
import sys
from repro.telemetry.source import TraceReplaySource
from repro.fleet import DeviceGrid, StreamingRollup   # lazy: no simulator
from repro.fleet.regression import scan_rollup
from repro.fleet.divergence import analyze_rollup

roll = StreamingRollup(bucket_s=120)
roll.add_grid("traced", TraceReplaySource({str(path)!r}).scrapes(),
              chips=128, app_mfu=0.38)
regs = scan_rollup(roll, factor_threshold=1.5)
rep = analyze_rollup(roll)
assert "traced" in regs, "regression not detected from replayed trace"
assert rep.flagged, "divergence triage missed the collapsed job"
for banned in ("repro.fleet.engine", "repro.fleet.jobs"):
    assert banned not in sys.modules, f"simulator leaked: {{banned}}"
print("REPLAY_OK", round(regs["traced"][0].factor, 2))
"""
    src_dir = str(Path(__file__).resolve().parent.parent / "src")
    res = subprocess.run([sys.executable, "-c", script],
                         env={"PYTHONPATH": src_dir, "PATH": "/usr/bin:/bin"},
                         capture_output=True, text=True)
    assert res.returncode == 0, res.stderr
    assert "REPLAY_OK" in res.stdout


# ---------------------------------------------------------------------------
# Stateful scrape cursors (PR 3: incremental collection)
# ---------------------------------------------------------------------------
def test_simulator_poll_cursor_covers_run_without_gaps():
    src = SimulatorSource(PROF, duration_s=600, interval_s=30.0,
                          n_devices=3, seed=7,
                          events=[Event(300, 600, slowdown=2.5)])
    grids = []
    while not src.exhausted:
        grids.append(src.poll(150))
    assert src.cursor_s == 600
    times = np.concatenate([g.times_s for g in grids])
    np.testing.assert_allclose(times, np.arange(1, 21) * 30.0)
    # events stay on the ABSOLUTE timeline across chunk boundaries
    tpa = np.concatenate([g.tpa for g in grids], axis=1)
    assert tpa[:, 10:].mean() < tpa[:, :10].mean() / 2
    # polls are deterministic given (seed, poll count)
    src2 = SimulatorSource(PROF, duration_s=600, interval_s=30.0,
                           n_devices=3, seed=7,
                           events=[Event(300, 600, slowdown=2.5)])
    np.testing.assert_array_equal(src2.poll(150).tpa, grids[0].tpa)


def test_poll_shorter_than_interval_rejected():
    src = SimulatorSource(PROF, duration_s=600, interval_s=30.0)
    with pytest.raises(ValueError, match="shorter than"):
        src.poll(10)


def test_set_interval_enforces_scrape_policy():
    src = SimulatorSource(PROF, duration_s=600, interval_s=30.0, seed=1)
    src.poll(60)
    src.set_interval(10.0)
    grid = src.poll(60)
    assert grid.interval_s == 10.0 and grid.tpa.shape[1] == 6
    assert np.isclose(grid.t0_s, 60.0)      # cursor carried across retiming
    with pytest.raises(ValueError, match="averaging window"):
        src.set_interval(45.0)              # §IV-C
    with pytest.raises(ValueError, match="positive"):
        src.set_interval(0.0)


def test_backend_source_poll_is_resumable():
    def series(chunks):
        bes = [SimulatedDeviceBackend(PROF, seed=s) for s in (0, 1)]
        src = BackendSource(bes, duration_s=180, interval_s=30.0)
        grids = [src.poll(c) for c in chunks]
        assert src.exhausted
        return np.concatenate([g.tpa for g in grids], axis=1)

    # backends advance their own clock: chunking must not change the data
    np.testing.assert_array_equal(series([180]), series([60, 60, 60]))
    # duration_s=inf makes a poll-only live source that never exhausts
    live = BackendSource([SimulatedDeviceBackend(PROF)],
                         duration_s=float("inf"), interval_s=30.0)
    assert live.poll(60).tpa.shape == (1, 2) and not live.exhausted


def test_trace_replay_poll_slices_recorded_times(tmp_path):
    grid = simulate_devices(PROF, duration_s=300, interval_s=30.0,
                            n_devices=2, seed=5)
    path = tmp_path / "t.csv"
    write_trace(grid, str(path))
    src = TraceReplaySource(str(path))
    assert not src.retimable
    with pytest.raises(ValueError, match="fixed"):
        src.set_interval(10.0)
    chunks = []
    while not src.exhausted:
        chunks.append(src.poll(120))
    got = np.concatenate([c.tpa for c in chunks if c.tpa.size], axis=1)
    np.testing.assert_array_equal(got, grid.tpa)
    times = np.concatenate([c.times_s for c in chunks if c.tpa.size])
    np.testing.assert_allclose(times, grid.times_s)


def test_set_interval_honors_source_strictness():
    # a strict=False source already runs degraded past the averaging
    # window; retiming within that same policy must not be rejected
    src = SimulatorSource(PROF, duration_s=600, interval_s=45.0,
                          n_devices=1, strict=False)
    with pytest.warns(RuntimeWarning, match="averaging window"):
        src.set_interval(40.0)
    assert src.interval_s == 40.0
    strict_src = SimulatorSource(PROF, duration_s=600, interval_s=30.0)
    with pytest.raises(ValueError, match="averaging window"):
        strict_src.set_interval(40.0)
