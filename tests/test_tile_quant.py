"""Tile quantization: closed form (Eq. 3/4) must equal the kernel grid
EXACTLY (0-FLOP error — tighter than the paper's <1000-FLOP nvJet match)."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core.tile_quant import (TilePolicy, correction_factor,
                                   effective_dims, overhead, pick_policy,
                                   profiled_flops, scale_factor_overhead,
                                   theoretical_flops)
from repro.kernels.gemm import grid_flops

dims = st.integers(min_value=1, max_value=5000)
tiles = st.sampled_from([128, 256, 512])
clusters = st.sampled_from([1, 2, 4])


@given(dims, dims, dims, tiles, tiles, tiles, clusters, clusters)
@settings(max_examples=200, deadline=None)
def test_closed_form_equals_kernel_grid(M, N, K, tm, tn, tk, cm, cn):
    pol = TilePolicy(tm, tn, tk, cm, cn)
    assert profiled_flops(M, N, K, pol) == grid_flops(M, N, K, pol)


@given(dims, dims, dims)
@settings(max_examples=100, deadline=None)
def test_overhead_nonnegative_and_bounded(M, N, K):
    pol = pick_policy(M, N, K)
    oh = overhead(M, N, K, pol)
    assert oh >= 0.0
    # worst case: every dim rounds nearly a full tile*cluster up
    me, ne, ke = effective_dims(M, N, K, pol)
    assert me >= M and ne >= N and ke >= K
    assert me < M + pol.tm * pol.cm
    assert ne < N + pol.tn * pol.cn
    assert ke < K + pol.tk


def test_paper_patterns():
    """Fig. 1 qualitative patterns: overhead decreases with size; aligned
    sizes at N>=4096 stay under ~9-12%; tiny sizes can exceed 50%."""
    pol = lambda n: pick_policy(n, n, n)
    big_aligned = [overhead(n, n, n, pol(n)) for n in range(4096, 16385, 128)]
    assert max(big_aligned) <= 0.12
    small = overhead(200, 200, 200, pol(200))
    assert small > 0.5
    # monotone-ish decrease in the mean across UNALIGNED size bands
    lo = np.mean([overhead(n, n, n, pol(n)) for n in range(515, 1024, 97)])
    hi = np.mean([overhead(n, n, n, pol(n)) for n in range(8195, 9216, 97)])
    assert hi < lo


def test_two_level_ceiling_eq4():
    """A matrix fitting exactly into tiles can still pad at cluster level."""
    pol = TilePolicy(512, 512, 512, cm=2, cn=1)
    # M = 3 tiles -> cluster rounds to 4 tiles
    me, _, _ = effective_dims(3 * 512, 512, 512, pol)
    assert me == 4 * 512


def test_correction_factor_inverts_overhead():
    pol = pick_policy(1000, 1000, 1000)
    cf = correction_factor(1000, 1000, 1000, pol)
    assert cf == pytest.approx(
        theoretical_flops(1000, 1000, 1000)
        / profiled_flops(1000, 1000, 1000, pol))
    assert cf <= 1.0


def test_scale_factor_overhead_shrinks_with_k():
    a = scale_factor_overhead(4096, 4096, 512, "int8")
    b = scale_factor_overhead(4096, 4096, 8192, "int8")
    assert a > b > 0
    assert scale_factor_overhead(4096, 4096, 512, "bf16") == 0.0
