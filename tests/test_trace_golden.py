"""Golden-trace compatibility: the wire formats are frozen by committed
fixture files, not by convention.

`tests/data/golden.{csv,jsonl,ctr}` were written once from the constants
below; every future refactor must (a) READ them back to exactly these
arrays, (b) WRITE byte-identical row traces from the same grid, and
(c) produce exactly the frozen rollup-bucket readout — so a change that
silently shifts parsing, serialization precision, or bucketing semantics
fails here before it corrupts an archive fleet."""
import json
import os

import numpy as np
import pytest

from repro.fleet.streaming import StreamingRollup
from repro.telemetry import TraceReader, read_trace, write_trace
from repro.telemetry.scrape import DeviceGrid

DATA = os.path.join(os.path.dirname(__file__), "data")

# the exact samples the fixtures hold: awkward floats on purpose
# (non-terminating binary fractions, repr-precision stress, exact zeros)
GOLD_TPA = np.array([
    [0.1, 1.0 / 3.0, 0.4123456789012345, 0.0, 1.0],
    [0.25, 0.5, 0.75, 0.125, 0.0078125],
])
GOLD_CLK = np.array([
    [1328.5, 1411.0, 1234.56789, 987.654321, 1300.0],
    [1400.0, 1111.125, 1250.0, 1327.9998779296875, 1399.25],
])
GOLD_IV, GOLD_T0 = 30.0, 600.0          # a mid-run slice: t in (600, 750]

# frozen bucket readout: bucket_s=60 over the grid above (buckets 0-9
# empty — the trace starts at t=600)
GOLD_BUCKET_WEIGHT = [0.0] * 10 + [4.0, 4.0, 2.0]
GOLD_BUCKET_MEAN = [float("nan")] * 10 + [
    0.2514576388888889, 0.2687614532488209, 0.4369772135416667]
GOLD_BUCKET_P50 = [float("nan")] * 10 + [
    0.24062500000000003, 0.11171875, 0.00859375]


def _gold_grid() -> DeviceGrid:
    return DeviceGrid(GOLD_IV, GOLD_TPA.copy(), GOLD_CLK.copy(),
                      t0_s=GOLD_T0)


@pytest.mark.parametrize("name", ["golden.csv", "golden.jsonl",
                                  "golden.ctr", "golden.ctr2"])
def test_golden_reads_are_exact(name):
    grid = read_trace(os.path.join(DATA, name))
    assert grid.interval_s == GOLD_IV
    assert grid.t0_s == GOLD_T0
    np.testing.assert_array_equal(grid.tpa, GOLD_TPA)
    np.testing.assert_array_equal(grid.clock_mhz, GOLD_CLK)
    np.testing.assert_array_equal(grid.times_s,
                                  GOLD_T0 + GOLD_IV * np.arange(1, 6))


@pytest.mark.parametrize("name", ["golden.csv", "golden.jsonl"])
def test_golden_row_writes_are_byte_identical(tmp_path, name):
    """Serialization itself is frozen: re-writing the golden grid must
    reproduce the committed fixture BYTE for byte."""
    out = tmp_path / name
    write_trace(_gold_grid(), str(out))
    with open(os.path.join(DATA, name), "rb") as fh:
        want = fh.read()
    assert out.read_bytes() == want


def test_golden_archive_layout_is_frozen():
    """The columnar manifest (format tag, geometry, chunk index) is part
    of the wire contract; npz chunk BYTES may vary across numpy/zlib, so
    the chunk contract is pinned by exact array reads instead."""
    with open(os.path.join(DATA, "golden.ctr", "manifest.json")) as fh:
        m = json.load(fh)
    assert m == {
        "format": "ctr-v1", "interval_s": 30.0, "n_devices": 2,
        "t0_s": 600.0, "dtype": "float64", "chunk_samples": 2,
        "n_samples": 5,
        "chunks": [
            {"file": "chunk-000000.npz", "t0_s": 600.0, "n_samples": 2},
            {"file": "chunk-000001.npz", "t0_s": 660.0, "n_samples": 2},
            {"file": "chunk-000002.npz", "t0_s": 720.0, "n_samples": 1},
        ],
    }
    rd = TraceReader(os.path.join(DATA, "golden.ctr"))
    assert [c.n_samples for c in rd.chunks] == [2, 2, 1]
    for k, grid in enumerate(rd.iter_chunks()):
        lo = 2 * k
        np.testing.assert_array_equal(grid.tpa,
                                      GOLD_TPA[:, lo:lo + 2])
        np.testing.assert_array_equal(grid.clock_mhz,
                                      GOLD_CLK[:, lo:lo + 2])
        assert grid.t0_s == GOLD_T0 + lo * GOLD_IV


def test_golden_v2_container_is_frozen(tmp_path):
    """The ctr-v2 single-file layout is part of the wire contract.

    `tests/data/golden.ctr2` was written once with the raw codec (whose
    encoding is deterministic native bytes, unlike zlib streams which
    may vary across library versions), so a re-write of the golden grid
    must reproduce the committed file BYTE for byte — magic, header
    json, chunk blocks, both cumulative footers, crcs and all.

    Regenerate (only after a deliberate, versioned format change):

        PYTHONPATH=src python tools/trace_convert.py \\
            tests/data/golden.csv tests/data/golden.ctr2 \\
            --chunk-samples 2 --codec raw
    """
    import struct

    from repro.telemetry import tracestore as ts

    fixture = os.path.join(DATA, "golden.ctr2")
    with open(fixture, "rb") as fh:
        blob = fh.read()

    # the immutable prelude: magic + header length + header json
    assert blob[:8] == ts.V2_MAGIC == b"CTR2\x00\x01\r\n"
    hlen = struct.unpack("<I", blob[8:12])[0]
    assert json.loads(blob[12:12 + hlen]) == {
        "format": "ctr-v2", "interval_s": 30.0, "n_devices": 2,
        "t0_s": 600.0, "chunk_samples": 2}

    # the newest footer: crc-guarded cumulative chunk table at EOF
    assert blob.endswith(ts.V2_FOOTER_MAGIC)
    tail = len(blob) - ts._V2_TAIL
    flen = struct.unpack("<Q", blob[tail + 4:tail + 12])[0]
    footer = json.loads(blob[tail - flen:tail])
    assert footer == {
        "format": "ctr-v2", "interval_s": 30.0, "n_devices": 2,
        "t0_s": 600.0, "dtype": "float64", "chunk_samples": 2,
        "n_samples": 5,
        "chunks": [
            {"off": 94, "t0_s": 600.0, "n": 2, "codec": "raw",
             "tb": 32, "cb": 32},
            {"off": 158, "t0_s": 660.0, "n": 2, "codec": "raw",
             "tb": 32, "cb": 32},
            {"off": 488, "t0_s": 720.0, "n": 1, "codec": "raw",
             "tb": 16, "cb": 16},
        ],
    }

    # writing the same grid again is byte-identical to the fixture
    out = tmp_path / "golden.ctr2"
    ts.write_archive(_gold_grid(), str(out), chunk_samples=2,
                     codec="raw")
    assert out.read_bytes() == blob

    # and the chunk contract reads back through the shared reader API
    rd = TraceReader(fixture)
    try:
        assert [c.n_samples for c in rd.chunks] == [2, 2, 1]
        for k, grid in enumerate(rd.iter_chunks()):
            lo = 2 * k
            np.testing.assert_array_equal(grid.tpa,
                                          GOLD_TPA[:, lo:lo + 2])
            assert grid.t0_s == GOLD_T0 + lo * GOLD_IV
    finally:
        rd.close()


@pytest.mark.parametrize("name", ["golden.csv", "golden.jsonl",
                                  "golden.ctr", "golden.ctr2"])
def test_golden_bucket_readout_is_frozen(name):
    """Bucketing semantics ride the same golden contract: the fixture
    through a bucket_s=60 rollup must land these exact buckets."""
    roll = StreamingRollup(bucket_s=60.0)
    roll.add_grid("golden", read_trace(os.path.join(DATA, name)))
    s = roll.job_stats("golden", qs=(50,))
    np.testing.assert_array_equal(s.weight, GOLD_BUCKET_WEIGHT)
    np.testing.assert_array_equal(s.mean, GOLD_BUCKET_MEAN)
    np.testing.assert_array_equal(s.percentiles[50], GOLD_BUCKET_P50)
