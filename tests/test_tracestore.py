"""Chunked columnar trace store: exact round-trips for arbitrary
geometries, streaming replay that is bucketwise identical to in-memory
replay for ANY chunk size and ANY poll-cursor pattern, O(chunk) peak
memory asserted via reader instrumentation, and loud failures for every
way an archive can be corrupt."""
import json
import os
import tempfile

import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.fleet.divergence import analyze_rollup
from repro.fleet.regression import scan_rollup
from repro.fleet.streaming import StreamingRollup
from repro.telemetry import (TraceReader, TraceReplaySource, TraceWriter,
                             read_trace, write_trace)
from repro.telemetry.scrape import DeviceGrid
from repro.telemetry.tracestore import (archive_nbytes, uniform_searchsorted,
                                        write_archive)


def _grid(n_dev=3, n_samples=40, interval_s=30.0, t0_s=0.0, seed=0,
          dtype=np.float64, collapse_from=None):
    """Synthetic counter grid; collapse_from injects a 2.5x duty drop at
    that sample index (detector material)."""
    rng = np.random.default_rng(seed)
    tpa = 0.4 + 0.02 * rng.standard_normal((n_dev, n_samples))
    if collapse_from is not None:
        tpa[:, collapse_from:] /= 2.5
    clk = 1350.0 + 20.0 * rng.standard_normal((n_dev, n_samples))
    return DeviceGrid(interval_s, np.clip(tpa, 0, 1).astype(dtype),
                      clk.astype(dtype), t0_s=t0_s)


def _assert_same_rollup(a: StreamingRollup, b: StreamingRollup, job: str):
    """Bucketwise identity, repo convention: histogram-derived state is
    bit-exact; value means match to 1e-12 (summation-order regrouping)."""
    for roll_s in ((a.job_stats(job), b.job_stats(job)),
                   (a.fleet_stats(), b.fleet_stats())):
        sa, sb = roll_s
        np.testing.assert_array_equal(sa.weight, sb.weight)
        np.testing.assert_allclose(sa.mean, sb.mean, atol=1e-12)
        for q in (10, 50, 90):
            np.testing.assert_array_equal(sa.percentiles[q],
                                          sb.percentiles[q])


def _assert_same_detections(a: StreamingRollup, b: StreamingRollup):
    ra = scan_rollup(a, window=3, min_duration=1, factor_threshold=1.5)
    rb = scan_rollup(b, window=3, min_duration=1, factor_threshold=1.5)
    assert sorted(ra) == sorted(rb)
    for jid in ra:
        assert [(r.start_idx, r.end_idx) for r in ra[jid]] \
            == [(r.start_idx, r.end_idx) for r in rb[jid]]
        np.testing.assert_allclose([r.factor for r in ra[jid]],
                                   [r.factor for r in rb[jid]], atol=1e-9)
    da = analyze_rollup(a, empty_ok=True)
    db = analyze_rollup(b, empty_ok=True)
    assert (da is None) == (db is None)
    if da is not None:
        assert [p.job_id for p in da.flagged] \
            == [p.job_id for p in db.flagged]


# ---------------------------------------------------------------------------
# Writer/reader round-trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("chunk", [1, 7, 40, 1000])
def test_archive_roundtrip_exact(tmp_path, dtype, chunk):
    grid = _grid(n_dev=2, n_samples=40, t0_s=900.0, dtype=dtype)
    path = str(tmp_path / "t.ctr")
    write_archive(grid, path, chunk_samples=chunk)
    rd = TraceReader(path)
    assert rd.n_samples == 40 and rd.n_devices == 2
    assert len(rd.chunks) == -(-40 // chunk)
    back = rd.read_all()
    assert back.tpa.dtype == dtype and back.t0_s == 900.0
    np.testing.assert_array_equal(back.tpa, grid.tpa)
    np.testing.assert_array_equal(back.clock_mhz, grid.clock_mhz)
    np.testing.assert_array_equal(back.times_s, grid.times_s)
    # chunk concatenation covers the archive exactly once
    parts = list(rd.iter_chunks())
    np.testing.assert_array_equal(
        np.concatenate([g.tpa for g in parts], axis=1), grid.tpa)
    assert [g.t0_s for g in parts] \
        == [900.0 + k * chunk * 30.0 for k in range(len(parts))]


def test_incremental_append_matches_oneshot(tmp_path):
    """A poll()-driven recorder (many small append_grid calls, then a
    reopen-append) produces the identical archive a one-shot write does."""
    grid = _grid(n_dev=2, n_samples=60, seed=3)
    one = str(tmp_path / "one.ctr")
    write_archive(grid, one, chunk_samples=16)
    inc = str(tmp_path / "inc.ctr")
    with TraceWriter(inc, 30.0, 2, chunk_samples=16) as w:
        for lo in range(0, 32, 4):
            w.append_grid(DeviceGrid(30.0, grid.tpa[:, lo:lo + 4],
                                     grid.clock_mhz[:, lo:lo + 4],
                                     t0_s=lo * 30.0))
    # restart the recorder: append=True resumes where the manifest ends
    with TraceWriter(inc, 30.0, 2, chunk_samples=16, append=True) as w:
        assert w.total_samples == 32
        w.append(grid.tpa[:, 32:], grid.clock_mhz[:, 32:])
    a, b = TraceReader(one), TraceReader(inc)
    assert [c.n_samples for c in a.chunks] == [c.n_samples for c in b.chunks]
    np.testing.assert_array_equal(a.read_all().tpa, b.read_all().tpa)
    np.testing.assert_array_equal(a.read_all().clock_mhz,
                                  b.read_all().clock_mhz)


def test_writer_validates_continuity(tmp_path):
    w = TraceWriter(str(tmp_path / "t.ctr"), 30.0, 2, chunk_samples=8)
    g = _grid(n_dev=2, n_samples=4)
    w.append_grid(g)
    with pytest.raises(ValueError, match="does not continue"):
        w.append_grid(g)                       # t0 rewinds to 0
    with pytest.raises(ValueError, match="interval"):
        w.append_grid(DeviceGrid(15.0, g.tpa, g.clock_mhz, t0_s=120.0))
    with pytest.raises(ValueError, match="devices"):
        w.append_grid(DeviceGrid(30.0, g.tpa[:1], g.clock_mhz[:1],
                                 t0_s=120.0))
    with pytest.raises(ValueError, match="misaligned"):
        w.append(g.tpa, g.clock_mhz[:1])
    w.close()
    with pytest.raises(ValueError, match="closed"):
        w.append(g.tpa, g.clock_mhz)
    with pytest.raises(ValueError, match="already a trace archive"):
        TraceWriter(str(tmp_path / "t.ctr"), 30.0, 2)


def test_writer_never_quantizes_silently(tmp_path):
    """A float64 append into a float32 archive must raise, not round;
    the narrowing direction (f32 data into an f64 archive) is exact and
    allowed."""
    g32 = _grid(n_dev=2, n_samples=4, dtype=np.float32)
    g64 = _grid(n_dev=2, n_samples=4, dtype=np.float64, t0_s=120.0)
    w = TraceWriter(str(tmp_path / "f32.ctr"), 30.0, 2)
    w.append_grid(g32)
    with pytest.raises(ValueError, match="without losing precision"):
        w.append_grid(g64)
    w.close()
    w = TraceWriter(str(tmp_path / "f64.ctr"), 30.0, 2)
    w.append_grid(_grid(n_dev=2, n_samples=4, dtype=np.float64))
    w.append_grid(DeviceGrid(30.0, g32.tpa, g32.clock_mhz, t0_s=120.0))
    w.close()
    back = TraceReader(str(tmp_path / "f64.ctr")).read_all()
    np.testing.assert_array_equal(back.tpa[:, 4:],
                                  g32.tpa.astype(np.float64))


def test_degenerate_grid_rejected_for_columnar(tmp_path):
    """write_trace of the empty grid a header-only CSV yields must fail
    with a clear message on the columnar path (row formats round-trip
    empty traces; an archive needs real geometry)."""
    empty_csv = tmp_path / "empty.csv"
    empty_csv.write_text("t_s,device,tpa,clock_mhz\n")
    grid = read_trace(str(empty_csv))
    assert grid.n_devices == 0
    with pytest.raises(ValueError, match="empty/degenerate"):
        write_trace(grid, str(tmp_path / "empty.ctr"))


def test_empty_archive(tmp_path):
    path = str(tmp_path / "empty.ctr")
    TraceWriter(path, 30.0, 2).close()
    rd = TraceReader(path)
    assert rd.n_samples == 0 and rd.duration_s == 0.0
    assert rd.read_all().tpa.shape == (2, 0)
    src = TraceReplaySource(path)
    assert src.exhausted


# ---------------------------------------------------------------------------
# Corruption is loud
# ---------------------------------------------------------------------------
def _valid_archive(tmp_path) -> str:
    path = str(tmp_path / "v.ctr")
    write_archive(_grid(n_dev=2, n_samples=10), path, chunk_samples=4)
    return path


def _edit_manifest(path, fn):
    mf = os.path.join(path, "manifest.json")
    with open(mf) as fh:
        m = json.load(fh)
    fn(m)
    with open(mf, "w") as fh:
        json.dump(m, fh)


def test_reader_rejects_corrupt_archives(tmp_path):
    with pytest.raises(ValueError, match="no manifest.json"):
        TraceReader(str(tmp_path))
    path = _valid_archive(tmp_path)

    _edit_manifest(path, lambda m: m.update(format="ctr-v99"))
    with pytest.raises(ValueError, match="format is 'ctr-v99'"):
        TraceReader(path)
    _edit_manifest(path, lambda m: m.update(format="ctr-v1", n_samples=99))
    with pytest.raises(ValueError, match="chunks hold"):
        TraceReader(path)
    _edit_manifest(path, lambda m: m.update(
        n_samples=10,
        chunks=[dict(c, t0_s=c["t0_s"] + 30.0) if i == 1 else c
                for i, c in enumerate(m["chunks"])]))
    with pytest.raises(ValueError, match="contiguous"):
        TraceReader(path)

    # regenerate a clean one, then break chunk files
    path2 = str(tmp_path / "v2.ctr")
    write_archive(_grid(n_dev=2, n_samples=10), path2, chunk_samples=4)
    os.remove(os.path.join(path2, "chunk-000001.npz"))
    with pytest.raises(ValueError, match="missing"):
        TraceReader(path2)

    path3 = str(tmp_path / "v3.ctr")
    write_archive(_grid(n_dev=2, n_samples=10), path3, chunk_samples=4)
    np.savez_compressed(os.path.join(path3, "chunk-000001.npz"),
                        tpa=np.zeros((2, 1)), clock_mhz=np.zeros((2, 1)))
    rd = TraceReader(path3)                    # manifest still consistent
    with pytest.raises(ValueError, match="manifest says"):
        rd.read_all()

    mf = os.path.join(path3, "manifest.json")
    with open(mf, "w") as fh:
        fh.write("{not json")
    with pytest.raises(ValueError, match="unreadable manifest"):
        TraceReader(path3)


def test_read_trace_rejects_interval_contradicting_manifest(tmp_path):
    path = _valid_archive(tmp_path)
    with pytest.raises(ValueError, match="contradicts"):
        read_trace(path, interval_s=15.0)
    assert read_trace(path, interval_s=30.0).tpa.shape == (2, 10)


# ---------------------------------------------------------------------------
# Streaming replay: O(chunk) memory, identical output
# ---------------------------------------------------------------------------
def test_uniform_searchsorted_matches_numpy():
    t0, iv, n = 570.0, 30.0, 200
    times = t0 + (np.arange(n) + 1) * iv
    for x in [0.0, t0, t0 + 1e-9, 600.0, 600.0 + 1e-9, 615.1, 5999.99,
              6000.0, 6570.0, 7000.0, -5.0]:
        assert uniform_searchsorted(t0, iv, n, x) \
            == int(np.searchsorted(times, x)), x


def test_multiday_chunked_replay_is_o_chunk_and_identical(tmp_path):
    """The acceptance case: a simulated multi-day trace replays through
    the collector-shaped poll loop holding O(chunk) samples — asserted
    via reader instrumentation — with detector output bucketwise
    identical to a fully-materialized replay."""
    iv, n_dev = 30.0, 4
    n_samples = 2 * 86400 // int(iv)             # two days of scrapes
    grid = _grid(n_dev=n_dev, n_samples=n_samples, interval_s=iv, seed=5,
                 collapse_from=n_samples // 2)
    chunk = 512
    path = str(tmp_path / "twoday.ctr")
    write_archive(grid, path, chunk_samples=chunk)

    round_s = 3600.0                             # 120 samples per round
    chunked = StreamingRollup(bucket_s=1800.0)
    src = TraceReplaySource(path)
    rounds = 0
    while not src.exhausted:
        g = src.poll(round_s)
        rounds += 1
        if g.tpa.size:
            chunked.add_grid("day-job", g, chips=64, app_mfu=0.30)
    assert rounds == 48

    rd = src.reader
    total_cells = n_dev * n_samples
    # a poll spans at most ceil(round/chunk_span)+1 = 2 chunks here
    assert rd.peak_resident_samples <= 2 * chunk * n_dev
    assert rd.peak_resident_samples < total_cells / 5
    # ... and exhaustion checks never forced extra decodes: every chunk
    # is decoded about once (cache carries boundary-crossing polls)
    assert rd.chunks_decoded <= len(rd.chunks) + rounds

    batch = StreamingRollup(bucket_s=1800.0)
    batch.add_grid("day-job", TraceReader(path).read_all(), chips=64,
                   app_mfu=0.30)
    _assert_same_rollup(chunked, batch, "day-job")
    _assert_same_detections(chunked, batch)
    # the injected mid-trace collapse is actually detected on both paths
    assert "day-job" in scan_rollup(chunked, window=3, min_duration=1)


def test_columnar_beats_csv_by_4x(tmp_path):
    """Acceptance: the columnar archive is >= 4x smaller than the same
    trace as CSV (float32 counters, implicit timestamps, compressed
    chunks vs ~50 B/sample of repr'd text)."""
    grid = _grid(n_dev=16, n_samples=480, dtype=np.float32, seed=2)
    csv_path = str(tmp_path / "t.csv")
    ctr_path = str(tmp_path / "t.ctr")
    write_trace(grid, csv_path)
    write_trace(grid, ctr_path, chunk_samples=2048)
    ratio = os.path.getsize(csv_path) / archive_nbytes(ctr_path)
    assert ratio >= 4.0, f"compression ratio {ratio:.2f}x < 4x"
    # and the smaller file still reads back exactly
    np.testing.assert_array_equal(read_trace(ctr_path).tpa, grid.tpa)


# ---------------------------------------------------------------------------
# Properties: arbitrary geometry, arbitrary cursors
# ---------------------------------------------------------------------------
@settings(max_examples=20)
@given(n_dev=st.integers(1, 3), n_samples=st.integers(1, 50),
       chunk=st.integers(1, 17), iv=st.sampled_from([5.0, 15.0, 30.0]),
       t0_steps=st.integers(0, 40), seed=st.integers(0, 2 ** 16),
       use_f32=st.booleans())
def test_property_roundtrip_exact(n_dev, n_samples, chunk, iv, t0_steps,
                                  seed, use_f32):
    # no pytest fixtures here: under the _propcheck shim @given-wrapped
    # tests take strategy kwargs only
    grid = _grid(n_dev=n_dev, n_samples=n_samples, interval_s=iv,
                 t0_s=t0_steps * iv, seed=seed,
                 dtype=np.float32 if use_f32 else np.float64)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "t.ctr")
        write_archive(grid, path, chunk_samples=chunk)
        back = TraceReader(path).read_all()
    assert back.tpa.dtype == grid.tpa.dtype
    assert back.t0_s == grid.t0_s and back.interval_s == iv
    np.testing.assert_array_equal(back.tpa, grid.tpa)
    np.testing.assert_array_equal(back.clock_mhz, grid.clock_mhz)


@settings(max_examples=15)
@given(n_samples=st.integers(4, 80), chunk=st.integers(1, 13),
       iv=st.sampled_from([15.0, 30.0]), t0_steps=st.integers(0, 10),
       seed=st.integers(0, 2 ** 16),
       poll_steps=st.lists(st.floats(0.4, 4.7), min_size=1, max_size=6),
       with_collapse=st.booleans())
def test_property_chunked_replay_matches_inmemory(
        n_samples, chunk, iv, t0_steps, seed, poll_steps, with_collapse):
    """For ANY chunk size, scrape interval, and mid-chunk poll-cursor
    pattern, streaming replay through the rollup + both detectors is
    bucketwise identical to materializing the whole trace."""
    grid = _grid(n_dev=2, n_samples=n_samples, interval_s=iv,
                 t0_s=t0_steps * iv, seed=seed,
                 collapse_from=n_samples // 2 if with_collapse else None)
    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "t.ctr")
    write_archive(grid, path, chunk_samples=chunk)

    chunked = StreamingRollup(bucket_s=4 * iv)
    src = TraceReplaySource(path)
    k = 0
    # cycle the (fractional-interval) poll durations: cursors land mid
    # sample, mid chunk, and past the end
    while not src.exhausted:
        g = src.poll(poll_steps[k % len(poll_steps)] * iv)
        k += 1
        if g.tpa.size:
            chunked.add_grid("job", g, chips=16, app_mfu=0.30)
    batch = StreamingRollup(bucket_s=4 * iv)
    batch.add_grid("job", TraceReader(path).read_all(), chips=16,
                   app_mfu=0.30)
    _assert_same_rollup(chunked, batch, "job")
    _assert_same_detections(chunked, batch)
    # every sample was replayed exactly once (weights conserve mass)
    assert float(np.nansum(chunked.job_stats("job").weight)) \
        == pytest.approx(grid.tpa.size * 16 / 2)


@settings(max_examples=10)
@given(chunk=st.integers(1, 9), seed=st.integers(0, 2 ** 16),
       cut_steps=st.integers(1, 30))
def test_property_seek_resumes_exactly(chunk, seed, cut_steps):
    """poll-to-T on one source == poll-to-cut + seek(cut) on another:
    the restart path loses no samples and duplicates none."""
    iv, n_samples = 30.0, 32
    grid = _grid(n_dev=2, n_samples=n_samples, interval_s=iv, seed=seed)
    path = os.path.join(tempfile.mkdtemp(), "t.ctr")
    write_archive(grid, path, chunk_samples=chunk)

    straight = TraceReplaySource(path)
    parts_a = []
    while not straight.exhausted:
        parts_a.append(straight.poll(5 * iv))

    cut = min(cut_steps, n_samples) * iv
    first = TraceReplaySource(path)
    parts_b = []
    while first.cursor_s < cut:
        parts_b.append(first.poll(min(5 * iv, cut - first.cursor_s)))
    resumed = TraceReplaySource(path)          # fresh process, same file
    resumed.seek(first.cursor_s)
    while not resumed.exhausted:
        parts_b.append(resumed.poll(5 * iv))

    got_a = np.concatenate([g.tpa for g in parts_a if g.tpa.size], axis=1)
    got_b = np.concatenate([g.tpa for g in parts_b if g.tpa.size], axis=1)
    np.testing.assert_array_equal(got_a, grid.tpa)
    np.testing.assert_array_equal(got_b, grid.tpa)
    times_b = np.concatenate([g.times_s for g in parts_b if g.tpa.size])
    np.testing.assert_allclose(times_b, grid.times_s)
