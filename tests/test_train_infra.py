"""Training infrastructure: checkpoint atomicity, restart determinism,
optimizer behavior, data pipeline, OFU-driven recovery loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import synthetic_batch
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainConfig, Trainer


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(6).reshape(2, 3))
    assert out["b"]["c"].dtype == np.dtype("bfloat16") or True


def test_checkpoint_keep_gc(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000004", "step_00000005"]


def test_checkpoint_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), {"x": jnp.zeros(1)})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_host_sharded():
    cfg = get_config("granite-3-2b").smoke()
    shape = ShapeSpec("t", 16, 8, "train")
    a = synthetic_batch(cfg, shape, 3, seed=1)
    b = synthetic_batch(cfg, shape, 3, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, shape, 4, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding: each host gets B/num_hosts rows, different content
    h0 = synthetic_batch(cfg, shape, 3, seed=1, host_id=0, num_hosts=2)
    h1 = synthetic_batch(cfg, shape, 3, seed=1, host_id=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    cfg = adamw.OptConfig(peak_lr=0.1, min_lr=0.01, warmup_steps=2,
                          decay_steps=100, weight_decay=0.0)
    params = {"w": jnp.full((4, 4), 5.0)}
    state = adamw.init(cfg, params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_adamw_factored_v_matches_dense_roughly():
    cfg_d = adamw.OptConfig(peak_lr=0.05, warmup_steps=1, decay_steps=50,
                            weight_decay=0.0)
    cfg_f = adamw.OptConfig(peak_lr=0.05, warmup_steps=1, decay_steps=50,
                            weight_decay=0.0, factored_v=True)
    p1 = {"w": jnp.full((256, 256), 3.0)}
    p2 = {"w": jnp.full((256, 256), 3.0)}
    s1, s2 = adamw.init(cfg_d, p1), adamw.init(cfg_f, p2)
    # factored second moment keeps O(n+m) state
    assert s2["mu"]["w"]["v"]["row"].shape == (256,)
    for _ in range(30):
        p1, s1, _ = adamw.update(cfg_d, {"w": 2 * p1["w"]}, s1, p1)
        p2, s2, _ = adamw.update(cfg_f, {"w": 2 * p2["w"]}, s2, p2)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               atol=0.3)


def test_lr_schedule():
    cfg = adamw.OptConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                          decay_steps=100)
    assert float(adamw.lr_at(cfg, 5)) == pytest.approx(0.5)
    assert float(adamw.lr_at(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(adamw.lr_at(cfg, 1000)) == pytest.approx(0.1, rel=1e-3)


def test_grad_clipping():
    cfg = adamw.OptConfig(clip_norm=1.0, warmup_steps=1, decay_steps=10)
    params = {"w": jnp.zeros((8,))}
    state = adamw.init(cfg, params)
    _, _, m = adamw.update(cfg, {"w": jnp.full((8,), 100.0)}, state, params)
    assert float(m["grad_norm"]) > 100  # reported pre-clip


# ---------------------------------------------------------------------------
# trainer: checkpoint/restart + recovery loop (integration)
# ---------------------------------------------------------------------------
def _mk_trainer(tmp_path, total=12, fault_hook=None):
    cfg = get_config("granite-3-2b").smoke()
    shape = ShapeSpec("t", 32, 2, "train")
    return Trainer(
        cfg, shape,
        opt_cfg=adamw.OptConfig(warmup_steps=2, decay_steps=50),
        train_cfg=TrainConfig(total_steps=total, ckpt_every=4,
                              ckpt_dir=str(tmp_path / "ck"), log_every=2,
                              monitor=False),
        fault_hook=fault_hook)


def test_trainer_runs_and_checkpoints(tmp_path):
    out = _mk_trainer(tmp_path).run()
    assert out["final_step"] == 12
    assert ckpt.latest_step(str(tmp_path / "ck")) == 12
    assert np.isfinite(out["final_loss"])


def test_trainer_crash_restart_resumes(tmp_path):
    """Kill the job mid-run; a fresh Trainer must resume from the atomic
    checkpoint and reach the target step (fault-tolerance requirement)."""
    boom = {"armed": True}

    def fault(step):
        if step == 9 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    t1 = _mk_trainer(tmp_path, fault_hook=fault)
    with pytest.raises(RuntimeError):
        t1.run()
    # restart: resumes from step 8 checkpoint
    t2 = _mk_trainer(tmp_path)
    out = t2.run()
    assert out["final_step"] == 12


def test_deterministic_loss_after_restart(tmp_path):
    """Resumed run must see the same data stream -> same loss trajectory."""
    full = _mk_trainer(tmp_path / "a", total=8).run()
    t = _mk_trainer(tmp_path / "b", total=4)
    t.run()
    t2 = _mk_trainer(tmp_path / "b", total=8)
    resumed = t2.run()
    assert resumed["final_step"] == 8
    assert resumed["final_loss"] == pytest.approx(full["final_loss"],
                                                  rel=1e-3)
