#!/usr/bin/env python
"""Execute the ```python code fences in markdown docs.

Keeps README.md / docs/ARCHITECTURE.md honest: every python snippet must
import and run cleanly against the current tree (CI runs this as the docs
job; tests/test_doc_snippets.py runs it in tier-1).

    PYTHONPATH=src python tools/check_doc_snippets.py README.md docs/*.md

Fences annotated ```python no-run (hardware-only wiring, illustrative
fragments) are skipped but still counted.  Each snippet runs in its own
namespace, in a temporary working directory so file-writing examples
leave no droppings.
"""
from __future__ import annotations

import os
import re
import sys
import tempfile
import traceback

FENCE = re.compile(r"^```python([^\n`]*)\n(.*?)^```\s*$",
                   re.MULTILINE | re.DOTALL)


def iter_snippets(text: str):
    """(info_string, code, line_number) for every python fence."""
    for m in FENCE.finditer(text):
        line = text.count("\n", 0, m.start()) + 1
        yield m.group(1).strip(), m.group(2), line


def check_file(path: str) -> tuple[int, int, list]:
    """(ran, skipped, failures) for one markdown file."""
    with open(path) as fh:
        text = fh.read()
    ran = skipped = 0
    failures = []
    for info, code, line in iter_snippets(text):
        if "no-run" in info:
            skipped += 1
            continue
        ns = {"__name__": "__doc_snippet__"}
        try:
            exec(compile(code, f"{path}:{line}", "exec"), ns)   # noqa: S102
            ran += 1
        except Exception:
            failures.append((path, line, traceback.format_exc()))
    return ran, skipped, failures


def main(paths) -> int:
    if not paths:
        print("usage: check_doc_snippets.py FILE.md [FILE.md ...]")
        return 2
    total_ran = total_skipped = 0
    failures = []
    start = os.getcwd()
    for path in paths:
        abspath = os.path.abspath(path)
        with tempfile.TemporaryDirectory() as tmp:
            os.chdir(tmp)
            try:
                ran, skipped, fails = check_file(abspath)
            finally:
                os.chdir(start)
        total_ran += ran
        total_skipped += skipped
        failures.extend(fails)
        print(f"{path}: {ran} snippet(s) ran, {skipped} skipped")
    for path, line, tb in failures:
        print(f"\nFAILED {path}:{line}\n{tb}", file=sys.stderr)
    if failures:
        print(f"{len(failures)} snippet(s) failed", file=sys.stderr)
        return 1
    if total_ran == 0:
        print("no runnable snippets found — nothing checked", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
