#!/usr/bin/env python
"""Correlate application-reported MFU against hardware OFU.

Three modes:

  * fixture sweep (default) — rebuild the paper's Table III fleet
    (`repro.fleet.table3`, 608 jobs incl. the §V-C miscalculated
    populations), run the offline correlation analysis, and print the
    headline numbers plus the flagged jobs:

        PYTHONPATH=src python tools/fleet_correlate.py
        PYTHONPATH=src python tools/fleet_correlate.py --seed 3 --json

  * log parse — extract a training job's reported throughput stream
    from its log (Megatron-style ``throughput per GPU (TFLOP/s/GPU):``
    lines), convert to MFU samples, and optionally ship them to a live
    fleet API's ``POST /v1/mfu``:

        PYTHONPATH=src python tools/fleet_correlate.py \
            --log train.log --job-id prod-llm-7b --peak-tflops 989 \
            --url http://fleethost:8080

  * ``--self-check`` — the CI gate: replay the FULL 608-job fixture
    through a live `Collector` into `FleetStore` + the HTTP query
    surface, and assert (a) the flagged set is EXACTLY the
    naive_moe/naive_hybrid populations on both the divergence and the
    correlation detector, (b) r-after-exclusion >= 0.75, (c) every
    per-job per-bucket number matches the offline
    `benchmarks/production_correlation.py` path bucketwise, (d) the
    log-line reporter and the ``POST /v1/mfu`` ingest round-trip.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:                        # ran without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.fleet import table3
from repro.fleet.correlation import analyze_correlation
from repro.fleet.divergence import analyze_rollup
from repro.telemetry.mfu import MfuReporter


def sweep(args) -> int:
    """Offline fixture sweep: the Fig. 5 / Table III numbers."""
    jobs = table3.build_jobs(args.seed)
    roll, mfu = table3.offline_rollups(jobs)
    crep = analyze_correlation(mfu, roll)
    if args.json:
        print(json.dumps(crep.to_payload(), indent=2))
        return 0
    print(crep.summary())
    rep = analyze_rollup(roll, flag_rel_err=args.flag_rel_err)
    print(f"divergence @ rel_err>{args.flag_rel_err:g}: "
          f"r_all={rep.r_all:.3f} r_after_exclusion={rep.r_clean:.3f} "
          f"flagged={len(rep.flagged)}")
    for f in crep.flagged[:args.top]:
        print(f"  {f.job_id:<14} ratio={f.ratio:5.2f}x "
              f"mfu={f.mfu * 100:5.1f}% ofu_adj={f.ofu_adj * 100:5.1f}% "
              f"buckets={f.n_buckets} ({f.direction})")
    if len(crep.flagged) > args.top:
        print(f"  ... and {len(crep.flagged) - args.top} more")
    return 0


def parse_log(args) -> int:
    """Parse a training log into MFU samples; optionally POST them."""
    reporter = MfuReporter(args.job_id, peak_tflops=args.peak_tflops)
    with open(args.log) as f:
        n = reporter.feed_log(f)
    if not n:
        print(f"no throughput lines found in {args.log}", file=sys.stderr)
        return 1
    samples = reporter.samples
    mean = sum(s.mfu for s in samples) / len(samples)
    print(f"{args.job_id}: {len(samples)} samples, "
          f"mean MFU {mean * 100:.2f}%, "
          f"last {samples[-1].mfu * 100:.2f}% "
          f"({samples[-1].tflops_per_gpu:.1f} TFLOP/s/GPU "
          f"/ {args.peak_tflops:g} peak)")
    if args.url:
        from repro.serve.client import FleetClient
        out = FleetClient(args.url).post_mfu(args.job_id, samples)
        print(f"POST /v1/mfu -> applied {out['applied']} rows")
    return 0


def self_check() -> int:
    """Replay the Table III fixture through the LIVE serve path and
    assert it matches the offline path bucketwise (CI gate)."""
    import numpy as np

    from repro.core.ofu import effective_peak
    from repro.core.peaks import DEFAULT_CHIP
    from repro.fleet.collector import Collector, CollectorConfig
    from repro.serve import (FleetAPIServer, FleetClient, FleetStore,
                             IngestAggregator)
    from repro.telemetry.mfu import compute_mfu, reported_tflops_per_gpu

    # -- offline half (the benchmarks/production_correlation.py path) --
    jobs = table3.build_jobs(0)
    truth = table3.affected_ids(jobs)
    affected = set().union(*truth.values())
    roll_off, mfu_off = table3.offline_rollups(jobs)
    rep_off = analyze_rollup(roll_off, flag_rel_err=table3.FLAG_REL_ERR)
    crep_off = analyze_correlation(mfu_off, roll_off)

    # -- live half: Collector rounds -> FleetStore -> HTTP queries -----
    col = Collector(table3.to_streams(jobs),
                    CollectorConfig(round_s=table3.ROUND_S,
                                    bucket_s=table3.BUCKET_S,
                                    flag_rel_err=table3.FLAG_REL_ERR))
    reports = col.run()
    miscalc_alerts = {a.job_id for a in col.alerts if a.kind == "miscalc"}
    assert miscalc_alerts == affected, (
        f"live miscalc alerts != ground truth: "
        f"extra={sorted(miscalc_alerts - affected)[:5]} "
        f"missing={sorted(affected - miscalc_alerts)[:5]}")

    store = FleetStore()
    store.update_from(col)
    agg = IngestAggregator(n_shards=2)
    with FleetAPIServer(store, aggregator=agg) as server:
        client = FleetClient(server.url)
        div = client.divergence(flag_rel_err=table3.FLAG_REL_ERR)
        corr = client.correlation()

        flagged_div = {f["job_id"] for f in div["flagged"]}
        flagged_corr = {f["job_id"] for f in corr["flagged"]}
        assert flagged_div == affected, (
            f"divergence flags != ground truth "
            f"({len(flagged_div)} vs {len(affected)})")
        assert flagged_corr == affected, (
            f"correlation flags != ground truth "
            f"({len(flagged_corr)} vs {len(affected)})")
        assert corr["r_clean"] >= 0.75, (
            f"r after exclusion {corr['r_clean']:.3f} < 0.75")
        # live serve numbers == offline numbers, not approximately
        for name, live, off in [
                ("divergence r_all", div["r_all"], rep_off.r_all),
                ("divergence r_clean", div["r_clean"], rep_off.r_clean),
                ("correlation r_all", corr["r_all"], crep_off.r_all),
                ("correlation r_clean", corr["r_clean"], crep_off.r_clean)]:
            assert abs(live - off) < 1e-9, f"{name}: {live} != {off}"

        # bucketwise identity, every job: counter AND mfu series
        for job in jobs:
            jid = job.job_id
            so = roll_off.job_stats(jid, qs=())
            sl = col.rollup.job_stats(jid, qs=())
            mo, ml = so.mean[~np.isnan(so.mean)], sl.mean[~np.isnan(sl.mean)]
            assert np.array_equal(mo, ml), f"{jid}: OFU buckets differ"
            io_, vo = mfu_off.job_series(jid)
            il, vl = col.mfu.job_series(jid)
            assert np.array_equal(io_, il) and np.array_equal(vo, vl), \
                f"{jid}: MFU buckets differ"

        # reporter round-trip: synthetic Megatron-style log -> samples
        peak = effective_peak({"bf16": 1.0}, DEFAULT_CHIP)
        tfl = reported_tflops_per_gpu("llama3.2-3b", 2.0, 64)
        lines = [f" iteration {10 * (k + 1)}/ 1000 | elapsed time per "
                 f"iteration (ms): 2000.0 | throughput per GPU "
                 f"(TFLOP/s/GPU): {tfl:.3f} |" for k in range(5)]
        rep = MfuReporter.for_chip("probe-3b")
        assert len(rep.feed_log(lines)) == 5
        want = compute_mfu(float(f"{tfl:.3f}"), peak)  # log-line rounding
        got = rep.samples[-1].mfu
        assert abs(got - want) < 1e-12, f"reporter MFU {got} != {want}"

        # POST /v1/mfu ingest round-trip through the aggregator
        out = client.post_mfu("probe-3b", rep.samples)
        assert out["applied"] == 5, out
        agg.publish(store, clock_s=col.clock_s)
        stats = client._get("/v1/ingest")
        assert stats["mfu_rows"] == 5 and stats["mfu_jobs"] == 1, stats

    print(f"SELF-CHECK OK: {len(jobs)} jobs x {len(reports)} rounds "
          f"through the live serve path; flagged set == "
          f"{{naive_moe: {len(truth['naive_moe'])}, naive_hybrid: "
          f"{len(truth['naive_hybrid'])}}} exactly on both detectors, "
          f"r_all={corr['r_all']:.3f} -> "
          f"r_after_exclusion={corr['r_clean']:.3f} (floor 0.75), "
          f"offline/live bucketwise identical, "
          f"reporter + POST /v1/mfu round-trip clean")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0,
                    help="fixture seed for the offline sweep")
    ap.add_argument("--flag-rel-err", type=float,
                    default=table3.FLAG_REL_ERR,
                    help="divergence exclusion threshold")
    ap.add_argument("--top", type=int, default=10,
                    help="flagged jobs to print")
    ap.add_argument("--json", action="store_true",
                    help="emit the full correlation payload as JSON")
    ap.add_argument("--log", default=None,
                    help="training log to parse for throughput lines")
    ap.add_argument("--job-id", default="job-0",
                    help="job id for parsed log samples")
    ap.add_argument("--peak-tflops", type=float, default=None,
                    help="per-GPU peak TFLOP/s for --log MFU conversion")
    ap.add_argument("--url", default=None,
                    help="fleet API base URL to POST parsed samples to")
    ap.add_argument("--self-check", action="store_true",
                    help="replay the 608-job fixture through the live "
                    "serve path and verify it against the offline path "
                    "(CI gate)")
    args = ap.parse_args(argv)
    if args.self_check:
        return self_check()
    if args.log:
        if args.peak_tflops is None:
            ap.error("--log requires --peak-tflops")
        return parse_log(args)
    return sweep(args)


if __name__ == "__main__":
    sys.exit(main())
