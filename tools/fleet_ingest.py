#!/usr/bin/env python
"""Run the fleet ingest tier: sharded aggregator behind POST /v1/ingest.

Serves a `FleetStore` + `IngestAggregator` on the dashboard API so
per-host daemons can ship `StreamingRollup.delta_bytes()` blobs at it:

    PYTHONPATH=src python tools/fleet_ingest.py --port 8080 \
        --shards 8 --publish-every 5
    # on each host:
    #   IngestClient("http://collector:8080", host_id, rollup).push()
    curl -s localhost:8080/v1/ingest | python -m json.tool   # counters
    curl -s localhost:8080/v1/fleet | python -m json.tool    # readout

`--publish-every N` reduces the host mirrors into a fresh `FleetStore`
generation every N seconds, so the read half stays a cache hit between
publishes no matter how hard ingest runs.

`--self-check` is the CI smoke: spin up the server on an ephemeral
port, run N fake host daemon threads pushing delta rounds over real
HTTP (with deliberate duplicate redeliveries), publish, and assert the
fleet totals match single-process ingestion of the same observations
bucketwise.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time

try:
    import repro  # noqa: F401
except ImportError:                        # ran without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np

from repro.fleet.streaming import StreamingRollup
from repro.serve import (FleetAPIServer, FleetClient, FleetStore,
                         IngestAggregator, IngestClient)


def serve(args) -> int:
    agg = IngestAggregator(n_shards=args.shards, max_queue=args.max_queue)
    store = FleetStore()
    with FleetAPIServer(store, host=args.host, port=args.port,
                        aggregator=agg) as server:
        print(f"ingest tier on {server.url} "
              f"({args.shards} shards, max_queue={args.max_queue})")
        print(f"  POST {server.url}/v1/ingest   (X-Fleet-Host: <id>)")
        print(f"  GET  {server.url}/v1/ingest   (counters)")
        print(f"  GET  {server.url}/v1/fleet    (published readout)")
        try:
            while True:
                time.sleep(args.publish_every)
                if agg.hosts:
                    agg.publish(store, clock_s=time.time())
                    print(f"published generation {store.generation}: "
                          f"{agg.hosts} hosts, "
                          f"{agg.stats()['applied']} deltas applied")
        except KeyboardInterrupt:
            print("\nstopping")
    return 0


def self_check(n_hosts: int = 8, rounds: int = 3) -> int:
    """N host daemons push delta rounds over real HTTP (some twice);
    the published fleet readout must match single-process ingestion of
    the same observations bucketwise (CI smoke)."""
    bins, bucket_s, n_buckets = 64, 300.0, 6
    agg = IngestAggregator(n_shards=4, max_queue=16)
    store = FleetStore()
    reference = StreamingRollup(bucket_s, bins=bins)
    ref_lock = threading.Lock()
    errors: list[BaseException] = []

    def host_daemon(url: str, h: int) -> None:
        rng = np.random.default_rng(h)
        roll = StreamingRollup(bucket_s, bins=bins)
        pusher = IngestClient(url, f"host-{h:02d}", roll, timeout_s=10.0)
        job, grp = f"job-{h % 3}", ("bf16" if h % 2 else "fp8")
        try:
            for r in range(rounds):
                hist = rng.poisson(2.0, (2, bins)).astype(float)
                sums = hist.sum(axis=1) * rng.uniform(0.2, 0.6)
                roll.observe_hist(job, hist, sums, b0=2 * r, group=grp,
                                  weight=16)
                with ref_lock:
                    reference.observe_hist(job, hist, sums, b0=2 * r,
                                           group=grp, weight=16)
                pusher.push()
                if h % 3 == 0:          # at-least-once: redeliver
                    stale = pusher.acked
                    pusher.acked = max(0, stale - 1)
                    pusher.push()
                    assert pusher.acked == stale, \
                        f"redelivery moved the cursor: {pusher.acked}"
        except BaseException as e:      # noqa: BLE001 — surfaced below
            errors.append(e)

    with FleetAPIServer(store, aggregator=agg) as server:
        threads = [threading.Thread(target=host_daemon,
                                    args=(server.url, h), daemon=True)
                   for h in range(n_hosts)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        if errors:
            raise errors[0]
        agg.publish(store, clock_s=1.0)

        fleet = agg.fleet_rollup()
        assert set(fleet._hists) == set(reference._hists), \
            "scope sets differ from single-process ingestion"
        for scope in reference._hists:
            np.testing.assert_allclose(
                fleet._hists[scope], reference._hists[scope],
                rtol=1e-9, atol=1e-12, err_msg=f"scope {scope}")
            np.testing.assert_allclose(
                fleet._sums[scope], reference._sums[scope],
                rtol=1e-9, atol=1e-12, err_msg=f"scope {scope}")

        stats = agg.stats()
        n_redelivered = sum(rounds for h in range(n_hosts) if h % 3 == 0)
        assert stats["hosts"] == n_hosts, stats
        # a redelivered delta carries an already-acked seq: the mirror
        # must shrug it off as a duplicate, never double-count
        assert stats["duplicates"] == n_redelivered, \
            f"expected {n_redelivered} duplicate redeliveries, " \
            f"aggregator saw {stats['duplicates']}"
        assert stats["gaps"] == 0 and stats["rejected"] == 0, stats

        client = FleetClient(server.url)
        readout = client.fleet()
        assert readout["t_s"], "published fleet series is empty"
        counters = client._get("/v1/ingest")
        assert counters["applied"] == stats["applied"], counters
    ref_w = float(sum(reference._hists[s].sum()
                      for s in reference._hists))
    print(f"SELF-CHECK OK: {n_hosts} host daemons x {rounds} delta "
          f"rounds over HTTP ({stats['applied']} applied, "
          f"{stats['duplicates']} duplicate redeliveries dropped), "
          f"fleet totals match single-process ingestion bucketwise "
          f"(total weight {ref_w:.0f})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (printed at startup)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=32,
                    help="per-shard in-flight submits before 429")
    ap.add_argument("--publish-every", type=float, default=5.0,
                    help="seconds between FleetStore publishes")
    ap.add_argument("--self-check", action="store_true",
                    help="fake host daemons over real HTTP, assert "
                    "fleet totals match single-process (CI smoke)")
    args = ap.parse_args(argv)
    if args.self_check:
        return self_check()
    return serve(args)


if __name__ == "__main__":
    sys.exit(main())
