#!/usr/bin/env python
"""Serve LIVE counter telemetry over the HTTP dashboard API.

Where `fleet_serve.py` replays recorded traces, this drives the
acquisition tier (`repro.telemetry.backends`): per-GPU
`DcgmFieldBackend`s over a pluggable transport feed a `BackendSource`,
and the rest of the pipeline — `Collector`, `ServiceDaemon`,
`FleetStore`, the JSON API — runs unchanged.

    # hardware-less demo: engine-driven fake transport, fast clock
    PYTHONPATH=src python tools/fleet_live.py --transport fake \
        --devices 4 --interval-s 30 --duration-s 3600 --replay-fast

    # real DCGM via the dcgmi CLI (one dmon snapshot per round)
    PYTHONPATH=src python tools/fleet_live.py --transport dcgmi \
        --interval-s 10 --round-s 60 --port 8080

    # NVML bindings (requires the pynvml module)
    PYTHONPATH=src python tools/fleet_live.py --transport pynvml

`--self-check` is the CI gate for the whole acquisition tier: it runs
the fake-transport pipeline end-to-end over real HTTP and asserts the
served rollup is BUCKETWISE-IDENTICAL to a pure `SimulatorSource`
pipeline on the same engine seed — transport, backend, retry and
source layers must be bit-transparent.  It also exercises the
reconnect path (injected transport faults must not change a single
sample) and the TPU backend over its fake transport.
"""
from __future__ import annotations

import argparse
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:                        # ran without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np

from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.serve import (FleetAPIServer, FleetClient, ServiceDaemon,
                         SimClock)
from repro.telemetry.backends import (DcgmiTransport, FakeDcgmTransport,
                                      PynvmlTransport, TransportError,
                                      make_dcgm_backends)
from repro.telemetry.counters import Event, StepProfile
from repro.telemetry.source import BackendSource

#: the demo step profile fake mode simulates (≈42% duty training job)
DEMO_PROFILE = StepProfile(mxu_time_s=0.84, step_time_s=2.0)


def _make_transport(args):
    if args.transport == "fake":
        events = [Event(args.duration_s / 2, args.duration_s,
                        slowdown=args.regression)] \
            if args.regression > 1.0 else []
        return FakeDcgmTransport(
            DEMO_PROFILE, duration_s=args.duration_s,
            interval_s=args.interval_s, n_devices=args.devices,
            chunk_s=args.round_s, events=events, seed=args.seed)
    if args.transport == "dcgmi":
        return DcgmiTransport()
    if args.transport == "pynvml":
        return PynvmlTransport()
    raise ValueError(f"unknown transport {args.transport!r}")


def _health_line(backends) -> str:
    return (f"backends: {sum(b.healthy for b in backends)}/"
            f"{len(backends)} healthy, "
            f"polls={sum(b.polls for b in backends)} "
            f"retries={sum(b.retries for b in backends)} "
            f"reconnects={sum(b.reconnects for b in backends)} "
            f"stale={sum(b.stale_reads for b in backends)}")


def serve(args) -> int:
    transport = _make_transport(args)
    try:
        transport.connect()
    except TransportError as e:
        print(f"transport {args.transport!r} unavailable: {e}",
              file=sys.stderr)
        return 2
    n = args.devices or transport.n_devices
    backends = make_dcgm_backends(transport, n, strict=not args.degraded)
    duration = args.duration_s if args.transport == "fake" \
        else float("inf")
    source = BackendSource(backends=backends, duration_s=duration,
                           interval_s=args.interval_s,
                           strict=not args.degraded)
    config = CollectorConfig(round_s=args.round_s, bucket_s=args.bucket_s,
                             retain=args.retain)
    daemon_kw = {}
    if args.replay_fast:
        clk = SimClock()
        daemon_kw.update(clock=clk.monotonic, sleep=clk.sleep)
    daemon = ServiceDaemon(
        Collector([JobStream(args.job_id, source)], config), **daemon_kw)
    with daemon, FleetAPIServer(daemon.store, host=args.host,
                                port=args.port) as server:
        print(f"live: {n} device(s) via {args.transport} transport, "
              f"interval {args.interval_s:g}s, round {args.round_s:g}s")
        print(f"serving on {server.url}  "
              f"({server.url}/v1/fleet, {server.url}/dashboard)")
        try:
            if args.rounds is not None or np.isfinite(duration):
                daemon.run(n_rounds=args.rounds)
            else:
                while True:          # live hardware: poll until ctrl-C
                    daemon.run(n_rounds=1)
                    print(_health_line(backends))
        except KeyboardInterrupt:
            print("\nstopping")
    print(_health_line(backends))
    return 0


def self_check() -> int:
    """CI gate: the fake-transport live pipeline over real HTTP must be
    bucketwise-identical to the pure-simulation pipeline on the same
    engine seed — and stay identical under injected transport faults."""
    from repro.telemetry.backends import FakeTpuTransport, TpuProfilerBackend
    from repro.telemetry.source import SimulatorSource

    n_dev, interval, duration, round_s, seed = 4, 30.0, 3600.0, 300.0, 7
    events = [Event(1800, 3600, slowdown=2.5)]
    config = CollectorConfig(round_s=round_s, bucket_s=round_s, retain=12,
                             detector={"window": 3, "min_duration": 1})

    def run_pipeline(source, job_id):
        """One daemon + HTTP server over `source`; returns the fleet
        series and the job's bucket series as served."""
        clk = SimClock()
        daemon = ServiceDaemon(Collector([JobStream(job_id, source)],
                                         config),
                               clock=clk.monotonic, sleep=clk.sleep)
        with daemon, FleetAPIServer(daemon.store) as server:
            daemon.run()
            client = FleetClient(server.url)
            return client.fleet(), client.job(job_id), client.alerts()

    def live_source(fail_every=None):
        transport = FakeDcgmTransport(
            DEMO_PROFILE, duration_s=duration, interval_s=interval,
            n_devices=n_dev, chunk_s=round_s, events=events, seed=seed,
            fail_every=fail_every)
        backends = make_dcgm_backends(transport, n_dev,
                                      sleep=lambda s: None)
        return backends, BackendSource(backends=backends,
                                       duration_s=duration,
                                       interval_s=interval)

    # live: FakeDcgmTransport -> DcgmFieldBackend -> BackendSource
    backends, src = live_source()
    live_fleet, live_job, live_alerts = run_pipeline(src, "live")
    assert all(b.healthy for b in backends)
    assert sum(b.polls for b in backends) == n_dev * duration / interval

    # reference: the pure simulator on the same seed + chunk cadence
    sim = SimulatorSource(profile=DEMO_PROFILE, duration_s=duration,
                          interval_s=interval, n_devices=n_dev, seed=seed,
                          events=events)
    sim_fleet, sim_job, sim_alerts = run_pipeline(sim, "live")

    # bucketwise identity, as served over HTTP
    assert live_fleet["t_s"] == sim_fleet["t_s"], "bucket grid differs"
    for key in ("mean", "p10", "p90"):
        if key in live_fleet and key in sim_fleet:
            assert live_fleet[key] == sim_fleet[key], \
                f"fleet {key} differs between live and sim"
    assert live_job == sim_job, "job bucket series differ"
    n_buckets = len(live_fleet["t_s"])
    assert n_buckets == duration / round_s, n_buckets

    # the injected regression is visible through the live path
    assert any(a["kind"] == "regression"
               for a in live_alerts["alerts"]), live_alerts

    # fault injection: reconnect-with-backoff must be sample-transparent
    flaky_backends, flaky_src = live_source(fail_every=97)
    flaky_fleet, flaky_job, _ = run_pipeline(flaky_src, "live")
    retries = sum(b.retries for b in flaky_backends)
    assert retries > 0, "fault injection never fired"
    assert flaky_fleet == live_fleet and flaky_job == live_job, \
        "retries changed served samples"
    assert all(b.healthy for b in flaky_backends)

    # TPU backend over its fake transport, same policy tier
    tpu = TpuProfilerBackend(0, FakeTpuTransport(
        DEMO_PROFILE, duration_s=600.0, interval_s=interval, n_devices=1,
        seed=seed))
    duty, clock_mhz = tpu.poll(interval)
    assert 0.0 <= duty <= 1.0 and clock_mhz > 0.0 and tpu.healthy

    print(f"SELF-CHECK OK: live fake-DCGM pipeline == simulator over "
          f"{n_buckets} HTTP-served buckets (bit-identical), regression "
          f"alert visible, {retries} injected faults recovered "
          f"transparently, TPU backend polls through the same tier")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", default="fake",
                    choices=["fake", "dcgmi", "pynvml"],
                    help="acquisition transport (default %(default)s)")
    ap.add_argument("--devices", type=int, default=0,
                    help="device count (0 = discover from transport; "
                    "fake transport defaults to 4)")
    ap.add_argument("--interval-s", type=float, default=10.0,
                    help="scrape interval (§IV-C caps at 30s)")
    ap.add_argument("--round-s", type=float, default=300.0)
    ap.add_argument("--bucket-s", type=float, default=300.0)
    ap.add_argument("--retain", type=int, default=24)
    ap.add_argument("--duration-s", type=float, default=3600.0,
                    help="fake-transport run length (real transports "
                    "poll until ctrl-C or --rounds)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="stop after N rounds")
    ap.add_argument("--regression", type=float, default=2.5,
                    help="fake mode: slowdown injected at half-run "
                    "(1.0 disables)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--job-id", default="live")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (printed at startup)")
    ap.add_argument("--replay-fast", action="store_true",
                    help="simulated clock: no sleeping between rounds "
                    "(fake transport only)")
    ap.add_argument("--degraded", action="store_true",
                    help="allow >30s intervals with a warning instead "
                    "of refusing (§IV-C strict=False)")
    ap.add_argument("--self-check", action="store_true",
                    help="prove live == sim bucketwise over HTTP and "
                    "exit (CI gate)")
    args = ap.parse_args(argv)
    if args.self_check:
        return self_check()
    if args.transport == "fake" and not args.devices:
        args.devices = 4
    return serve(args)


if __name__ == "__main__":
    sys.exit(main())
