#!/usr/bin/env python
"""Replay the labeled scenario library and score every detector.

Runs `repro.scenarios.run_scorecard` over the scenario library (or a
subset), prints one BENCH line per (scenario, detector) with precision /
recall / time-to-detect, merges the cases into `BENCH_fleet.json`
(alongside the engine benchmark's cases — merge is by case name, so the
two suites coexist), and writes the full scorecard document:

    PYTHONPATH=src python tools/fleet_scorecard.py
    PYTHONPATH=src python tools/fleet_scorecard.py \
        --scenario gloo_regression_2p5x --engine vector --json card.json

`--self-check` is the CI gate: run the whole library and fail (exit 1)
when any pinned precision / recall / time-to-detect floor in
`repro.scenarios.scorecard.FLOORS` regresses.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:                        # ran without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.scenarios import (FLOORS, check_floors, run_scorecard,
                             scenario_names)


def _bench_cases(doc: dict) -> list:
    """Flatten the scorecard into BENCH_fleet.json case rows — one per
    (scenario, detector), named `scorecard/<scenario>/<detector>`."""
    cases = []
    for scen, entry in sorted(doc["scenarios"].items()):
        for det, s in sorted(entry["detectors"].items()):
            metrics = {"precision": s["precision"], "recall": s["recall"],
                       "ttd_s": s["ttd_s"], "n_alerts": s["n_alerts"],
                       "n_labels": s["n_labels"]}
            cases.append({"name": f"scorecard/{scen}/{det}",
                          "median": s["precision"], "units": "precision",
                          "metrics": metrics})
    return cases


def _merge_bench_json(cases: list) -> str:
    """Merge scorecard cases into BENCH_fleet.json by case name, keeping
    any cases other suites (benchmarks/fleet_engine.py) already wrote."""
    path = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")
    doc = {"schema": 1, "suite": "fleet_engine", "cases": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev.get("cases"), list):
                doc = prev
        except (json.JSONDecodeError, OSError):
            pass                 # corrupt file: rewrite from scratch
    fresh = {c["name"] for c in cases}
    doc["cases"] = [c for c in doc["cases"]
                    if c.get("name") not in fresh] + cases
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def run(names, *, engine: str, json_path=None, write_bench=True) -> int:
    doc = run_scorecard(names, engine=engine)
    cases = _bench_cases(doc)
    for c in cases:
        print("BENCH " + json.dumps({"name": c["name"], **c["metrics"]}))
    if write_bench:
        path = _merge_bench_json(cases)
        print(f"BENCH-JSON {path} cases={len(cases)}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"scorecard written to {json_path}")
    # a partial run (--scenario) checks only the floors it measured; the
    # full sweep keeps the "missing from scorecard" guard
    floors = FLOORS if names is None else {
        k: v for k, v in FLOORS.items() if k[0] in doc["scenarios"]}
    bad = check_floors(doc, floors)
    for v in bad:
        print(f"FLOOR VIOLATION: {v}", file=sys.stderr)
    n = sum(len(e["detectors"]) for e in doc["scenarios"].values())
    print(f"scorecard: {len(doc['scenarios'])} scenarios, {n} "
          f"(scenario, detector) cells, {len(bad)} floor violations")
    return 1 if bad else 0


def self_check() -> int:
    """CI gate: the whole library must hold every pinned floor."""
    print(f"self-check: {len(scenario_names())} scenarios, "
          f"{len(FLOORS)} pinned floors")
    return run(None, engine="fused")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME", choices=scenario_names(),
                    help="score only this scenario (repeatable; "
                         "default: all)")
    ap.add_argument("--engine", default="fused",
                    choices=["fused", "vector", "scalar", "jax"],
                    help="simulation backend (faults are post-hoc, so "
                         "ground truth is identical on all of them)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full scorecard document here")
    ap.add_argument("--no-bench-json", action="store_true",
                    help="skip merging cases into BENCH_fleet.json")
    ap.add_argument("--self-check", action="store_true",
                    help="CI gate: full library, fail on any floor "
                         "violation")
    args = ap.parse_args(argv)
    if args.self_check:
        return self_check()
    return run(args.scenario, engine=args.engine, json_path=args.json,
               write_bench=not args.no_bench_json)


if __name__ == "__main__":
    sys.exit(main())
