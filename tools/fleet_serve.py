#!/usr/bin/env python
"""Serve recorded fleet traces over the HTTP dashboard API.

Builds one `JobStream` per trace (job id = file stem), runs them through
a windowed `Collector` inside a `ServiceDaemon`, and serves the
`FleetStore` on `repro.serve.http`'s JSON API:

    PYTHONPATH=src python tools/fleet_serve.py day-a.ctr day-b.ctr \
        --port 8080 --round-s 300 --replay-fast
    curl -s localhost:8080/v1/fleet | python -m json.tool
    curl -s 'localhost:8080/v1/query?kind=top_regressions&k=3'

`--replay-fast` replays on a simulated clock (no sleeping — an archive
browser); without it rounds pace on the real wall clock like a live
deployment.  `--state-dir/--persist-every` enable restartable snapshots
(restored automatically when the state dir already holds one), and
`--tee-dir` re-records everything polled into per-job columnar archives.

`--self-check` is the CI smoke: record a synthetic regressed trace,
serve it through a full daemon on an ephemeral port, hit every endpoint
family with `FleetClient`, and assert 200s plus an ETag 304 on repeat.
"""
from __future__ import annotations

import argparse
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:                        # ran without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.fleet.collector import Collector, CollectorConfig, JobStream
from repro.serve import (FleetAPIServer, FleetClient, ServiceDaemon,
                         SimClock)
from repro.telemetry.source import TraceReplaySource


def _streams(paths, interval_s=None):
    streams = []
    for path in paths:
        job_id = os.path.splitext(os.path.basename(path.rstrip("/")))[0]
        streams.append(JobStream(
            job_id, TraceReplaySource(path, interval_s=interval_s)))
    return streams


def serve(args) -> int:
    streams = _streams(args.traces, interval_s=args.interval_s)
    config = CollectorConfig(round_s=args.round_s, bucket_s=args.bucket_s,
                             retain=args.retain,
                             detector={"window": args.window,
                                       "min_duration": args.min_duration})
    daemon_kw = dict(persist_every=args.persist_every,
                     tee_dir=args.tee_dir)
    if args.replay_fast:
        clk = SimClock()
        daemon_kw.update(clock=clk.monotonic, sleep=clk.sleep)
    if args.state_dir and os.path.isfile(
            os.path.join(args.state_dir, "daemon_state.json")):
        daemon = ServiceDaemon.restore(args.state_dir, streams, config,
                                       **daemon_kw)
        print(f"restored daemon state from {args.state_dir} "
              f"(round {daemon.collector.round_idx})")
    else:
        daemon = ServiceDaemon(Collector(streams, config),
                               state_dir=args.state_dir, **daemon_kw)
    with daemon, FleetAPIServer(daemon.store, host=args.host,
                                port=args.port) as server:
        print(f"serving {len(streams)} job stream(s) on {server.url}")
        print(f"  {server.url}/v1/fleet")
        print(f"  {server.url}/v1/jobs")
        print(f"  {server.url}/v1/alerts")
        print(f"  {server.url}/v1/query?kind=top_regressions&k=5")
        try:
            daemon.run(n_rounds=args.rounds)
            print("replay exhausted; still serving final state "
                  "(ctrl-C to exit)" if args.serve_after else
                  "replay exhausted")
            if args.serve_after:
                import threading
                threading.Event().wait()
        except KeyboardInterrupt:
            print("\nstopping")
    return 0


def self_check() -> int:
    """Daemon over a replay archive on an ephemeral port; all endpoint
    families must 200 and a repeat poll must 304 (CI smoke)."""
    import tempfile

    from repro.fleet.engine import simulate_devices
    from repro.telemetry.counters import Event, StepProfile
    from repro.telemetry.source import write_trace

    prof = StepProfile(mxu_time_s=0.84, step_time_s=2.0)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "selfcheck.ctr")
        grid = simulate_devices(prof, duration_s=3600, interval_s=30.0,
                                events=[Event(1800, 3600, slowdown=2.5)],
                                n_devices=4, seed=7)
        write_trace(grid, path, chunk_samples=64)
        clk = SimClock()
        config = CollectorConfig(round_s=300, bucket_s=300, retain=12,
                                 detector={"window": 3, "min_duration": 1})
        daemon = ServiceDaemon(Collector(_streams([path]), config),
                               clock=clk.monotonic, sleep=clk.sleep)
        with daemon, FleetAPIServer(daemon.store) as server:
            reports = daemon.run()
            client = FleetClient(server.url)
            fleet = client.fleet()
            assert fleet["t_s"], "fleet series is empty"
            jobs = client.jobs()
            assert jobs["jobs"] == ["selfcheck"], jobs
            job = client.job("selfcheck")
            assert len(job["mean"]) == len(fleet["mean"])
            alerts = client.alerts()
            assert any(a["kind"] == "regression"
                       for a in alerts["alerts"]), alerts
            worst = client.top_regressions(k=3, window=3, min_duration=1)
            assert worst["regressions"] \
                and worst["regressions"][0]["factor"] > 1.8
            assert client.goodput()["weighted_ofu"] is not None
            # the poller pattern: unchanged generation => ETag 304
            before = client.hits_304
            again = client.fleet()
            assert client.hits_304 == before + 1, "no 304 on repeat"
            assert again == fleet
            n304 = client.hits_304
    print(f"SELF-CHECK OK: {len(reports)} rounds served, all endpoint "
          f"families 200, repeat poll -> 304 ({n304} cache hit), "
          f"regression visible at /v1/query")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="*",
                    help="trace files/archives; job id = file stem")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (printed at startup)")
    ap.add_argument("--round-s", type=float, default=300.0)
    ap.add_argument("--bucket-s", type=float, default=300.0)
    ap.add_argument("--retain", type=int, default=24)
    ap.add_argument("--window", type=int, default=4,
                    help="regression detector reference window")
    ap.add_argument("--min-duration", type=int, default=2)
    ap.add_argument("--interval-s", type=float, default=None,
                    help="scrape interval for single-poll row traces")
    ap.add_argument("--rounds", type=int, default=None,
                    help="stop after N rounds (default: run to exhaustion)")
    ap.add_argument("--replay-fast", action="store_true",
                    help="simulated clock: no sleeping between rounds")
    ap.add_argument("--serve-after", action="store_true",
                    help="keep serving the final state after replay ends")
    ap.add_argument("--state-dir", default=None,
                    help="snapshot persistence dir (auto-restores)")
    ap.add_argument("--persist-every", type=int, default=0,
                    help="persist state every N rounds")
    ap.add_argument("--tee-dir", default=None,
                    help="re-record polled grids as per-job .ctr archives")
    ap.add_argument("--self-check", action="store_true",
                    help="serve a synthetic archive end-to-end and exit "
                    "(CI smoke test)")
    args = ap.parse_args(argv)
    if args.self_check:
        return self_check()
    if not args.traces:
        ap.error("at least one trace is required (or pass --self-check)")
    return serve(args)


if __name__ == "__main__":
    sys.exit(main())
