#!/usr/bin/env python
"""Convert scrape traces between CSV / JSONL (interchange) and the
columnar archive formats (`repro.telemetry.tracestore`): the ctr-v1
chunk directory and the ctr-v2 single-file container, with a stats
summary for sizing archives.

    PYTHONPATH=src python tools/trace_convert.py fleet.csv fleet.ctr \
        --chunk-samples 4096
    PYTHONPATH=src python tools/trace_convert.py fleet.ctr fleet.ctr2 \
        --codec dbz-zlib
    PYTHONPATH=src python tools/trace_convert.py fleet.ctr2 fleet.jsonl
    PYTHONPATH=src python tools/trace_convert.py --self-check

Formats are inferred from the path (`.csv`, `.jsonl`/`.ndjson`/`.json`,
`.ctr` directory or `.ctr2` file — an existing archive of either
version is sniffed regardless of suffix) unless forced with
`--from/--to`.  `--codec` selects the ctr-v2 column codec (see
`repro.telemetry.codecs`; v1 output is always npz).  `--self-check`
round-trips a synthetic trace through all formats in a temp dir and
verifies exact equality plus chunked replay — the CI smoke test for
the storage layer.
"""
from __future__ import annotations

import argparse
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:                        # ran without PYTHONPATH=src
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np

from repro.telemetry import codecs, tracestore
from repro.telemetry.source import _resolve_fmt, read_trace, write_trace


def _nbytes(path: str) -> int:
    return tracestore.archive_nbytes(path) if os.path.isdir(path) \
        else os.path.getsize(path)


def _describe(tag: str, path: str, grid) -> None:
    n = grid.tpa.shape[1]
    span_h = n * grid.interval_s / 3600.0 if n else 0.0
    size = _nbytes(path)
    per = size / max(grid.tpa.size, 1)
    print(f"  {tag}: {path}")
    print(f"    devices={grid.n_devices} samples/device={n} "
          f"interval={grid.interval_s:g}s span={span_h:.2f}h "
          f"t0={grid.t0_s:g}s")
    print(f"    {size:,} bytes ({per:.1f} B/sample)")


def convert(src: str, dst: str, *, src_fmt: str = "auto",
            dst_fmt: str = "auto", chunk_samples: int,
            interval_s: float | None = None,
            codec: str | None = None) -> None:
    grid = read_trace(src, fmt=src_fmt, interval_s=interval_s)
    write_trace(grid, dst, fmt=dst_fmt, chunk_samples=chunk_samples,
                codec=codec)
    _describe("in ", src, grid)
    _describe("out", dst, grid)
    ratio = _nbytes(src) / max(_nbytes(dst), 1)
    print(f"    size ratio in/out: {ratio:.1f}x")
    if _resolve_fmt(dst, dst_fmt) == "columnar":
        print(f"    {tracestore.TraceReader(dst).summary()}")


def self_check() -> int:
    """Round-trip a synthetic trace csv -> ctr -> jsonl and verify exact
    equality + chunked replay; returns a process exit code."""
    import tempfile

    from repro.telemetry.scrape import DeviceGrid
    from repro.telemetry.source import TraceReplaySource

    rng = np.random.default_rng(7)
    grid = DeviceGrid(
        30.0,
        rng.uniform(0.0, 1.0, (3, 50)).astype(np.float32),
        rng.uniform(900.0, 1411.0, (3, 50)).astype(np.float32),
        t0_s=600.0)
    with tempfile.TemporaryDirectory() as tmp:
        csv = os.path.join(tmp, "t.csv")
        ctr = os.path.join(tmp, "t.ctr")
        ctr2 = os.path.join(tmp, "t.ctr2")
        jsonl = os.path.join(tmp, "t.jsonl")
        write_trace(grid, csv)
        convert(csv, ctr, chunk_samples=8)
        convert(ctr, ctr2, chunk_samples=8)
        convert(ctr2, jsonl, chunk_samples=8)
        a = read_trace(ctr)
        a2 = read_trace(ctr2)
        b = read_trace(jsonl)
        np.testing.assert_array_equal(a.tpa, grid.tpa)
        np.testing.assert_array_equal(a.clock_mhz, grid.clock_mhz)
        # v1 -> v2 conversion is bit-exact, not just value-equal
        assert a2.tpa.tobytes() == a.tpa.tobytes()
        assert a2.clock_mhz.tobytes() == a.clock_mhz.tobytes()
        np.testing.assert_array_equal(b.tpa, grid.tpa.astype(np.float64))
        assert a.t0_s == a2.t0_s == b.t0_s == 600.0
        # chunked replay covers every sample exactly once
        src = TraceReplaySource(ctr)
        parts = []
        while not src.exhausted:
            g = src.poll(250.0)
            if g.tpa.size:
                parts.append(g.tpa)
        np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                      grid.tpa)
        assert src.reader.peak_resident_samples < grid.tpa.size
    print("SELF-CHECK OK: csv -> ctr -> ctr2 -> jsonl exact, chunked "
          "replay complete, peak residency O(chunk)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("src", nargs="?", help="input trace (csv/jsonl/ctr)")
    ap.add_argument("dst", nargs="?", help="output trace (csv/jsonl/ctr)")
    ap.add_argument("--from", dest="src_fmt", default="auto",
                    choices=["auto", "csv", "jsonl", "columnar"])
    ap.add_argument("--to", dest="dst_fmt", default="auto",
                    choices=["auto", "csv", "jsonl", "columnar"])
    ap.add_argument("--chunk-samples", type=int,
                    default=tracestore.DEFAULT_CHUNK_SAMPLES,
                    help="samples per columnar chunk (columnar output "
                    "only; default %(default)s)")
    ap.add_argument("--interval-s", type=float, default=None,
                    help="scrape interval for single-poll row traces")
    ap.add_argument("--codec", default=None,
                    choices=[None, "auto"] + codecs.codec_names(),
                    help="ctr-v2 column codec (default: auto — "
                    f"{codecs.DEFAULT_CODEC}; .ctr2 output only)")
    ap.add_argument("--self-check", action="store_true",
                    help="round-trip a synthetic trace through all "
                    "formats and exit (CI smoke test)")
    args = ap.parse_args(argv)
    if args.self_check:
        return self_check()
    if not args.src or not args.dst:
        ap.error("src and dst are required (or pass --self-check)")
    convert(args.src, args.dst, src_fmt=args.src_fmt,
            dst_fmt=args.dst_fmt, chunk_samples=args.chunk_samples,
            interval_s=args.interval_s, codec=args.codec)
    return 0


if __name__ == "__main__":
    sys.exit(main())
